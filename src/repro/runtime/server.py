"""Serving runtime: continuous batching with chunked streamed prefill.

The serving analogue of the paper's case study: prefill is the one-sided
bulk transfer of the prompt into the cache (the ``gasnet_put``), decode is
the ART pattern of many small transfers.  PR 5 rebuilds both on the
pipeline scheduler:

* **Admission** is per slot: a request's prompt is prefilled into a
  full-length K/V scratch by incremental *chunk steps*
  (``dist/steps.build_prefill_chunk_step`` over
  ``models/prefill.prefill_chunk``), at most one chunk per server step, so
  prefill work interleaves with decode steps instead of blocking them —
  chunked prefill admission kills the head-of-line blocking a long prompt
  used to impose on every decoding request.  The finished scratch is
  converted into a single-request cache and written into its batch row
  with one donated ``dynamic_update_slice`` per leaf
  (``build_slot_write_step`` — the per-slot PUT).  Every arch in the zoo
  rides this path with its own chunk carry
  (``configs.base.chunk_carry_spec``: K/V ring rows, MLA latents,
  constant-size SSD state, the hybrid pair, encoder-once cross-K/V); the
  one runtime gate is ``models/prefill.chunk_support`` (the blockwise
  attention impl), and a gated arch — or ``prefill_chunk=None`` — admits
  with one bulk per-slot prefill instead, *with* a build warning and a
  ``stats()['admission_mode']`` signal (same numerics, whole-prompt
  latency).  Chunk sizes round up to the carry's ``chunk_multiple`` so
  SSD state hand-offs stay on ``ssm_chunk`` boundaries.
* **Decode** runs the donated ``build_serve_step`` with ``sample=True``:
  per-slot positions let every cache row advance independently, argmax
  runs on device, and the server fetches one stacked ``(B,)`` id vector
  per step instead of per-slot logits syncs.

**Paged KV pool** (PR 6, ``ServerConfig.paged``): the monolithic per-rank
cache becomes a pool of fixed-size KV blocks addressed through a per-slot
block table (``models/decode.init_paged_cache``).  Admission converts the
finished prefill into pool blocks and pushes only the *private* ones with
one donated block-write (``dist/steps.build_block_write_step`` — the
block-granular ``gasnet_put``; ``core/pgas.BlockSegment`` is the global
addressing it models); a host-side ref-counted :class:`BlockPool` runs the
free list and the prefix cache, so identical prompt prefixes are admitted
once and aliased copy-on-write into many slots' tables.  Decode through
the table is bit-identical to the contiguous ring (asserted by
tests/test_serving.py).

TTFT accounting: ``Request.first_token`` is stamped when the request's
first *decode token id* has actually been sampled and fetched — never at
prefill completion — and stays correct under chunked admission because the
stamp rides the token append, not the scheduler phase.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig, chunk_carry_spec
from repro.dist.steps import (
    StepConfig,
    build_block_write_step,
    build_prefill_chunk_step,
    build_prefill_step,
    build_serve_step,
    build_slot_write_step,
)
from repro.models.decode import (
    init_cache,
    init_paged_cache,
    kv_buf_len,
    paged_slot_blocks,
    supports_paged,
)
from repro.models.prefill import (
    cache_to_blocks,
    chunk_support,
    init_prefill_scratch,
    prefill_chunk_cuts,
    scratch_to_blocks,
    scratch_to_cache,
    seed_scratch_from_blocks,
)


class BlockPool:
    """Host-side ref-counted free list over the paged KV pool.

    Block ids ``[0, reserved)`` are *parking* blocks (one per batch row —
    an idle row's table points at its own parking block so its dead decode
    writes can never touch allocated blocks) and are never handed out.
    Every other id is either on the free list or ref-counted live: one ref
    per slot whose table maps the block, plus one per prefix-cache entry
    that pins it.  Entries are LRU-evicted (their refs dropped) when
    ``alloc`` runs short — blocks still mapped by running requests survive
    eviction of the entry that cached them (copy-on-write sharing).
    """

    def __init__(self, n_blocks: int, reserved: int = 0):
        self.n_blocks = int(n_blocks)
        self.reserved = int(reserved)
        assert 0 <= self.reserved <= self.n_blocks
        # LIFO free list, low ids first out (nicer to read in tests)
        self._free = list(range(self.n_blocks - 1, self.reserved - 1, -1))
        self._refs: Dict[int, int] = {}
        self._entries: "dict[bytes, List[int]]" = {}   # insertion = LRU order
        self.evictions = 0
        self._lost: set = set()          # ids on failed partitions
        self._quarantined: set = set()   # lost ids already swept off free/live

    # -- invariant surface (the hypothesis tests drive these) ---------------

    @property
    def free_blocks(self) -> int:
        """Blocks immediately available to ``alloc``."""
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        """Blocks with at least one reference (slots or cache entries)."""
        return len(self._refs)

    @property
    def cached_entries(self) -> int:
        """Resident prefix-cache entries."""
        return len(self._entries)

    @property
    def lost_blocks(self) -> int:
        """Ids on dead partitions (``fail_partition``), reserved included."""
        return len(self._lost)

    @property
    def quarantined_blocks(self) -> int:
        """Lost non-reserved ids swept out of circulation — admission's
        capacity target shrinks by exactly this many blocks while a
        partition is quarantined."""
        return len(self._quarantined)

    def evictable_blocks(self) -> int:
        """Blocks that evicting *every* idle prefix-cache entry would
        return to the free list: pinned only by cache entries, on a live
        partition.  Blocks shared with running requests (COW) stay live
        after eviction and do not count."""
        pins: Dict[int, int] = {}
        for bids in self._entries.values():
            for b in bids:
                pins[b] = pins.get(b, 0) + 1
        return sum(1 for b, p in pins.items()
                   if self._refs.get(b, 0) == p and b not in self._lost)

    def usable_blocks(self) -> int:
        """Upper bound on what one ``alloc`` can deliver: free now plus
        everything cache eviction could recover."""
        return len(self._free) + self.evictable_blocks()

    def can_cover(self, n: int) -> bool:
        """True when ``alloc(n)`` would succeed — *without* touching the
        cache.  Admission consults this so a burst during quarantine
        defers requests instead of wiping the prefix cache on a doomed
        claim."""
        return int(n) <= self.usable_blocks()

    def check_conservation(self):
        """Every non-reserved block is free xor referenced xor quarantined
        — no leaks, no aliasing between the free list and live tables, and
        the invariant *holds across a partition shrink*: a lost block is
        quarantined the moment its last reference drops (or immediately,
        when it was free), never re-entering circulation."""
        assert (self.free_blocks + self.live_blocks
                + len(self._quarantined)) \
            == self.n_blocks - self.reserved, (
                self.free_blocks, self.live_blocks,
                len(self._quarantined), self.n_blocks)
        assert not set(self._free) & set(self._refs)
        assert not set(self._free) & self._quarantined
        assert not self._quarantined & set(self._refs)
        # a quarantined block is always a lost one
        assert self._quarantined <= self._lost

    # -- partition shrink (decode-rank loss) ---------------------------------

    def partition(self, rank: int, n_ranks: int) -> range:
        """Contiguous id range owned by decode rank ``rank`` of
        ``n_ranks`` — the pool's PGAS segment map (each rank backs an
        equal contiguous span of block ids, remainders to the tail)."""
        assert 0 <= rank < n_ranks, (rank, n_ranks)
        lo = rank * self.n_blocks // n_ranks
        hi = (rank + 1) * self.n_blocks // n_ranks
        return range(lo, hi)

    def fail_partition(self, rank: int, n_ranks: int) -> frozenset:
        """Mark rank ``rank``'s id span dead and shrink the pool around it.

        Free lost ids quarantine immediately; live lost ids stay counted
        as live until their holders drain and ``release`` them (at which
        point they quarantine instead of returning to the free list);
        prefix-cache entries pinning any lost block are purged (their pin
        refs dropped — surviving entries keep serving COW hits).  Returns
        the lost id set so the server can find the victim slots.
        """
        return self.fail_partitions([rank], n_ranks)

    def fail_partitions(self, ranks, n_ranks: int) -> frozenset:
        """Batch form of :meth:`fail_partition`: quarantine the union of
        several ranks' id spans in **one** sweep — the multi-rank-loss
        path, where every rank missing the same lease deadline is
        excluded atomically (one free-list rebuild, one cache purge,
        conservation held throughout)."""
        lost = frozenset(b for r in ranks
                         for b in self.partition(r, n_ranks))
        self._lost |= lost
        self._free = [b for b in self._free if b not in lost]
        self._quarantined |= {b for b in lost
                              if b >= self.reserved and b not in self._refs}
        for key in [k for k, bids in self._entries.items()
                    if set(bids) & lost]:
            self.release(self._entries.pop(key))
        return lost

    def restore_partition(self, rank: int, n_ranks: int) -> frozenset:
        """Re-admit rank ``rank``'s id span — the scale-out/rejoin path.

        Quarantined ids in the span return to the free list (descending
        order, so low ids still pop first); reserved parking ids are
        simply un-lost.  Ids still referenced (a straggler holding a lost
        block that never drained) stay out until their refs drop — they
        are un-lost here, so ``release`` will free them normally.
        Returns the restored id set.
        """
        span = frozenset(self.partition(rank, n_ranks)) & self._lost
        back = sorted((b for b in span & self._quarantined), reverse=True)
        self._quarantined -= span
        self._lost -= span
        self._free.extend(back)
        return span

    # -- alloc / refcount ----------------------------------------------------

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` blocks off the free list (one ref each), LRU-evicting
        idle prefix-cache entries under pressure; raises ``MemoryError``
        when the pool genuinely cannot cover the request.

        The feasibility check runs *first*: a doomed claim (``n`` beyond
        free + evictable, e.g. an alloc burst while a partition is
        quarantined) raises without evicting anything, so the prefix
        cache survives the failure instead of being wiped for nothing.
        """
        if not self.can_cover(n):
            raise MemoryError(
                f"block pool exhausted: want {n}, free {len(self._free)}, "
                f"evictable {self.evictable_blocks()}, "
                f"quarantined {len(self._quarantined)}")
        while len(self._free) < n and self._entries:
            self._evict_lru()
        if len(self._free) < n:
            raise MemoryError(
                f"block pool exhausted: want {n}, free {len(self._free)}")
        bids = [self._free.pop() for _ in range(n)]
        for b in bids:
            self._refs[b] = 1
        return bids

    def retain(self, bids: List[int]):
        """Add one reference to each (already live) block."""
        for b in bids:
            if b not in self._refs:
                raise ValueError(f"retain of unallocated block {b}")
            self._refs[b] += 1

    def release(self, bids: List[int]):
        """Drop one reference from each block; blocks reaching zero return
        to the free list — or to quarantine when their partition died
        (``fail_partition``), so a lost id never re-enters circulation.
        Releasing a free block raises (double free)."""
        for b in bids:
            if b not in self._refs:
                raise ValueError(f"double free of block {b}")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                if b in self._lost:
                    if b >= self.reserved:
                        self._quarantined.add(b)
                else:
                    self._free.append(b)

    # -- prefix cache --------------------------------------------------------

    def cache_insert(self, key: bytes, bids: List[int]):
        """Pin ``bids`` (one extra ref each) as the cached blocks of prompt
        prefix ``key``; a no-op if the key is already resident."""
        if key in self._entries:
            return
        self.retain(bids)
        self._entries[key] = list(bids)

    def cache_lookup(self, key: bytes) -> Optional[List[int]]:
        """If ``key`` is resident, retain its blocks for the caller and
        return them (freshest LRU position); else ``None``."""
        if key not in self._entries:
            return None
        bids = self._entries.pop(key)
        self._entries[key] = bids                     # move to LRU tail
        self.retain(bids)
        return list(bids)

    def _evict_lru(self):
        key = next(iter(self._entries))
        self.release(self._entries.pop(key))
        self.evictions += 1


@dataclasses.dataclass
class ServerConfig:
    """Continuous-batching knobs (see docs/serving.md)."""

    max_batch: int = 8
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1               # -1: disabled (synthetic workloads)
    greedy: bool = True
    #: tokens per admitted prefill chunk (the streamed-prefill ART chunk);
    #: None/0 admits with one bulk per-slot prefill instead
    prefill_chunk: Optional[int] = 16
    #: paged KV pool: decode gathers each row's ring through a per-slot
    #: block table (bit-identical to the contiguous cache)
    paged: bool = False
    #: KV positions per pool block; must divide the ring extent and (for
    #: prefix caching) be a multiple of ``prefill_chunk``
    block_size: int = 16
    #: pool size; default = parking row per slot + a full table per slot
    #: + one spare table's worth of prefix-cache headroom
    n_blocks: Optional[int] = None
    #: admit identical prompt prefixes once (shared ref-counted blocks)
    prefix_cache: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    frontend_embeds: Optional[np.ndarray] = None   # frontend (vlm) archs
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted: float = 0.0
    first_token: Optional[float] = None
    finished: Optional[float] = None
    cancelled: bool = False
    # scheduler state (not part of the public result surface)
    phase: str = "queued"          # queued | prefill | decode
    _scratch: Optional[dict] = None
    _cursor: int = 0               # next prompt position to prefill
    _blocks: List[int] = dataclasses.field(default_factory=list)
    _shared: int = 0               # leading blocks aliased from the cache
    _recovered: bool = False       # drained off a dead rank, awaiting re-admit


class Server:
    """Fixed-slot continuous-batching server over the serve step bundles."""

    def __init__(self, cfg: ModelConfig, params, mesh, scfg=None,
                 srv: ServerConfig = ServerConfig(), fault_plan=None,
                 membership=None):
        self.cfg, self.params, self.srv = cfg, params, srv
        self.mesh = mesh
        self.scfg = scfg or StepConfig()
        self.fault_plan = fault_plan
        # live detector path: a MembershipService polled every tick; its
        # view changes (not the scripted plan) drive fail/admit below
        self.membership = membership
        assert srv.greedy, "only greedy sampling is implemented"
        ok, why = chunk_support(cfg)
        if srv.prefill_chunk and not ok:
            # never fall back silently: admission mode is a serving
            # property the operator asked for
            warnings.warn(
                f"{cfg.name}: chunked prefill requested "
                f"(prefill_chunk={srv.prefill_chunk}) but unsupported — "
                f"{why}; admitting with bulk per-slot prefill",
                stacklevel=2)
        self._chunkable = ok and bool(srv.prefill_chunk)
        self._fallback_reason = ("" if self._chunkable
                                 else (why if srv.prefill_chunk
                                       else "prefill_chunk disabled"))
        # chunk sizes round up to the carry contract's multiple (SSD state
        # hand-off is bit-exact only on ssm_chunk boundaries)
        mult = chunk_carry_spec(cfg).chunk_multiple
        self._eff_chunk = (-(-int(srv.prefill_chunk) // mult) * mult
                           if self._chunkable else 0)
        self._paged = bool(srv.paged)
        if self._paged:
            assert supports_paged(cfg), \
                f"{cfg.name} has no paged-cache layout"
            self._sb = kv_buf_len(cfg, srv.max_seq)
            self._blk = int(srv.block_size)
            self._npb = paged_slot_blocks(cfg, srv.max_seq, self._blk)
            self._n_blocks = int(srv.n_blocks or
                                 srv.max_batch * (1 + self._npb) + self._npb)
            if srv.prefix_cache and self._chunkable:
                assert self._blk % self._eff_chunk == 0, (
                    "prefix caching needs block_size to be a multiple of "
                    f"the effective chunk ({self._blk} % {self._eff_chunk})")
            self.pool = BlockPool(self._n_blocks, reserved=srv.max_batch)
            self.bundle = build_serve_step(
                cfg, mesh, self.scfg, batch=srv.max_batch,
                max_seq=srv.max_seq, sample=True,
                block_size=self._blk, n_blocks=self._n_blocks)
        else:
            self.pool = None
            self.bundle = build_serve_step(cfg, mesh, self.scfg,
                                           batch=srv.max_batch,
                                           max_seq=srv.max_seq, sample=True)
        self.writer = build_slot_write_step(cfg, mesh, srv.max_batch,
                                            srv.max_seq)
        from repro.dist.sharding import to_shardings
        self._cache_sh = to_shardings(mesh, self.bundle.in_specs[1])
        self._slot_sh = to_shardings(mesh, self.writer.in_specs[1])
        if self._paged:
            blk, nb = self._blk, self._n_blocks
            self.cache = jax.jit(
                lambda: init_paged_cache(cfg, srv.max_batch, srv.max_seq,
                                         blk, nb),
                out_shardings=self._cache_sh)()
            npb, sb = self._npb, self._sb

            def _park(cache, i):
                out = dict(cache)
                out["block_ids"] = lax.dynamic_update_slice_in_dim(
                    cache["block_ids"],
                    jnp.broadcast_to(i.astype(jnp.int32), (1, npb)),
                    i, axis=0)
                out["slot_pos"] = lax.dynamic_update_slice_in_dim(
                    cache["slot_pos"], jnp.full((1, sb), -1, jnp.int32),
                    i, axis=0)
                out["pos"] = lax.dynamic_update_slice_in_dim(
                    cache["pos"], jnp.zeros((1,), jnp.int32), i, axis=0)
                return out

            self._park_fn = jax.jit(
                _park, in_shardings=(self._cache_sh, None),
                out_shardings=self._cache_sh, donate_argnums=(0,))
        else:
            self.cache = jax.jit(
                lambda: init_cache(cfg, srv.max_batch, srv.max_seq),
                out_shardings=self._cache_sh)()
        self._chunk_bundles: Dict[tuple, object] = {}   # (S, lo, C) -> bundle
        self._bulk_bundles: Dict[int, object] = {}      # S -> fn
        self._scratch_inits: Dict[int, object] = {}     # S -> jitted init
        self._finish_fns: Dict[int, object] = {}        # S -> jitted convert
        self._blocks_fns: Dict[int, object] = {}        # S -> jitted convert
        self._seed_fns: Dict[tuple, object] = {}        # (S, m) -> jitted
        self._block_writers: Dict[int, object] = {}     # n_write -> bundle
        self.slots: List[Optional[Request]] = [None] * srv.max_batch
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_tok = np.zeros((srv.max_batch,), np.int32)
        self.prefix_hits = 0
        self.prefix_misses = 0
        self._ticks = 0
        self._dead_slots: set = set()   # rows whose parking block died
        self.recoveries = 0             # drain/re-admit cycles survived
        self.reprefilled_tokens = 0     # positions re-prefilled on recovery

    @property
    def chunked_admission(self) -> bool:
        """Whether admission actually runs as streamed prefill chunks.
        False means every prompt admits with one bulk per-slot prefill —
        either ``ServerConfig.prefill_chunk`` is disabled or the arch is
        gated out by ``models/prefill.chunk_support`` (in which case the
        constructor warned and ``stats()['admission_fallback']`` carries
        the reason)."""
        return self._chunkable

    def _eff_len(self, s: int) -> int:
        """Prefill-row count of an ``s``-token prompt: vlm frontend rows
        prefix the token rows (they are positions in the same scratch);
        encdec frames feed the encoder, not the decoder stream."""
        if self.cfg.frontend and self.cfg.family != "encdec":
            return s + self.cfg.frontend_tokens
        return s

    # -- request intake -------------------------------------------------------

    def submit(self, prompt: np.ndarray,
               frontend_embeds: Optional[np.ndarray] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        eff = self._eff_len(prompt.size)
        assert prompt.ndim == 1 and 0 < eff <= self.srv.max_seq, (
            prompt.shape, self.srv.max_seq)
        if self.cfg.family == "encdec":
            assert prompt.size <= self.cfg.decoder_max_seq, prompt.shape
        if self.cfg.frontend:
            assert frontend_embeds is not None, (
                f"{self.cfg.name} requires frontend embeddings per request")
            frontend_embeds = np.asarray(frontend_embeds, np.float32)
            assert frontend_embeds.shape == (self.cfg.frontend_tokens,
                                             self.cfg.frontend_dim), \
                frontend_embeds.shape
        rid = len(self.queue) + len(self.done) + sum(s is not None
                                                     for s in self.slots)
        req = Request(rid=rid, prompt=prompt,
                      frontend_embeds=frontend_embeds,
                      submitted=time.perf_counter())
        self.queue.append(req)
        return rid

    def _admit(self):
        """Assign queued requests to free slots (state only — their prompts
        are prefilled chunk-by-chunk between the following decode steps).
        Paged admission also claims the slot's pool blocks here, reusing
        ref-counted prefix-cache blocks when the prompt's leading full
        blocks are already resident; a dry pool leaves the request queued
        (backpressure) until a retire frees blocks."""
        for i, slot in enumerate(self.slots):
            if i in self._dead_slots:
                continue        # parking block lost: row capacity is gone
            if slot is None and self.queue:
                req = self.queue[0]
                if self._paged and not self._claim_blocks(req):
                    break
                self.queue.pop(0)
                req.phase = "prefill"
                req._cursor = 0
                if self._chunkable:
                    se = self._eff_len(int(req.prompt.size))
                    req._scratch = self._scratch_init(se)()
                    if self._paged and req._shared:
                        req._scratch = self._seed_fn(se, req._shared)(
                            req._scratch, self.cache,
                            jnp.asarray(req._blocks[:req._shared],
                                        jnp.int32))
                        req._cursor = (req._shared * self._blk
                                       // self._eff_chunk)
                if req._recovered:
                    # the surviving committed prefix came back COW
                    # (``_shared`` blocks); only the rest re-prefills
                    req._recovered = False
                    self.reprefilled_tokens += (
                        self._eff_len(int(req.prompt.size))
                        - req._shared * (self._blk if self._paged else 0))
                self.slots[i] = req

    # -- paged block accounting ----------------------------------------------

    def _share_ok(self, s: int) -> bool:
        """Whether a prompt of length ``s`` may alias prefix-cache blocks:
        sharing is copy-on-write (shared blocks are never rewritten), so
        decode must be provably unable to ring-wrap into them."""
        return (self._paged and self.srv.prefix_cache and self._chunkable
                and self.cfg.window is None and not self.cfg.frontend
                and s + self.srv.max_new_tokens <= self._sb)

    def _m_max(self, s: int) -> int:
        """Most leading *full* blocks of an ``s``-token prompt that can be
        shared — at least one token (one chunk) must remain to prefill, so
        the final chunk's logits can emit the first decode token."""
        return min((s - 1) // self._blk, self._npb)

    def _claim_blocks(self, req: Request) -> bool:
        """Claim the slot's ``S_buf/blk`` pool blocks: the longest resident
        prompt prefix supplies shared blocks (retained, not copied), the
        rest come off the free list.  False = pool dry, leave queued."""
        s = int(req.prompt.size)
        shared: List[int] = []
        if self._share_ok(s):
            for m in range(self._m_max(s), 0, -1):
                got = self.pool.cache_lookup(
                    req.prompt[:m * self._blk].tobytes())
                if got is not None:
                    shared = got
                    break
            if shared:
                self.prefix_hits += 1
            else:
                self.prefix_misses += 1
        need = self._npb - len(shared)
        if not self.pool.can_cover(need):
            # quarantine backpressure: the capacity target shrank, so a
            # burst defers (stays queued) instead of wiping the prefix
            # cache on a claim that cannot succeed anyway
            if shared:
                self.pool.release(shared)
                self.prefix_hits -= 1
                self.prefix_misses += 1
            return False
        try:
            private = self.pool.alloc(need)
        except MemoryError:
            if shared:
                self.pool.release(shared)
                self.prefix_hits -= 1
                self.prefix_misses += 1
            return False
        req._blocks = shared + private
        req._shared = len(shared)
        return True

    def _scratch_specs(self, se: int):
        """Shardings of the size-``se`` prefill scratch (committed arrays
        must match the chunk bundles' in-sharding exactly)."""
        from repro.dist.sharding import cache_pspecs, to_shardings
        cfg = self.cfg
        shape = jax.eval_shape(lambda: init_prefill_scratch(cfg, 1, se))
        return to_shardings(self.mesh,
                            cache_pspecs(cfg, self.mesh, shape))

    def _seed_fn(self, se: int, m: int):
        """Jitted prefix-hit seeder: gather ``m`` shared blocks out of the
        pool into positions ``[0, m·blk)`` of a fresh scratch (donated),
        so chunked prefill resumes at the first uncached chunk."""
        key = (se, m)
        if key not in self._seed_fns:
            cfg = self.cfg
            ssh = self._scratch_specs(se)

            def _seed(scratch, cache, bids):
                bk = jnp.take(cache["kp"], bids, axis=1)
                bv = jnp.take(cache["vp"], bids, axis=1)
                return seed_scratch_from_blocks(cfg, scratch, bk, bv)

            self._seed_fns[key] = jax.jit(
                _seed, in_shardings=(ssh, self._cache_sh, None),
                out_shardings=ssh, donate_argnums=(0,))
        return self._seed_fns[key]

    def _blocks_fn(self, s: int):
        """Jitted scratch→pool-blocks conversion (the paged finish)."""
        if s not in self._blocks_fns:
            cfg, max_seq, blk = self.cfg, self.srv.max_seq, self._blk
            self._blocks_fns[s] = jax.jit(
                lambda scr: scratch_to_blocks(cfg, scr, blk,
                                              cache_len=max_seq),
                donate_argnums=(0,))
        return self._blocks_fns[s]

    def _block_writer(self, n_write: int):
        if n_write not in self._block_writers:
            self._block_writers[n_write] = build_block_write_step(
                self.cfg, self.mesh, self.srv.max_batch, self.srv.max_seq,
                self._blk, self._n_blocks, n_write)
        return self._block_writers[n_write]

    def _install_paged(self, i: int, req: Request, blocks):
        """Push the slot's private blocks into the pool and install its
        table row — then register every full-block prompt prefix with the
        prefix cache (nested entries, so future prompts match the longest
        common prefix block-chain)."""
        bk, bv, slot_pos_row, pos_row = blocks
        m = req._shared
        table = jnp.asarray(req._blocks, jnp.int32)
        self.cache = self._block_writer(self._npb - m).fn(
            self.cache, bk[:, m:], bv[:, m:], table[m:], table,
            slot_pos_row, pos_row, jnp.int32(i))
        s = int(req.prompt.size)
        if self._share_ok(s):
            for m2 in range(1, self._m_max(s) + 1):
                self.pool.cache_insert(
                    req.prompt[:m2 * self._blk].tobytes(),
                    req._blocks[:m2])

    # -- prefill scheduling ---------------------------------------------------

    def _chunk_bundle(self, se: int, lo: int, c: int,
                      n_fe: Optional[int] = None):
        """Chunk-step bundle for a size-``se`` scratch at offset ``lo``.
        ``n_fe``: frontend rows riding this chunk (the vlm fe-row slice,
        or the full frame tensor on the encdec chunk 0)."""
        key = (se, lo, c, n_fe)
        if key not in self._chunk_bundles:
            wf = ((n_fe, self.cfg.frontend_dim) if n_fe is not None
                  else None)
            self._chunk_bundles[key] = build_prefill_chunk_step(
                self.cfg, self.mesh, self.scfg, batch=1, prompt_len=se,
                lo=lo, chunk_len=c, with_frontend=wf)
        return self._chunk_bundles[key]

    def _scratch_init(self, se: int):
        """Jitted scratch allocator, sharded like the chunk step's input."""
        if se not in self._scratch_inits:
            cfg = self.cfg
            self._scratch_inits[se] = jax.jit(
                lambda: init_prefill_scratch(cfg, 1, se),
                out_shardings=self._scratch_specs(se))
        return self._scratch_inits[se]

    def _bulk_fn(self, s: int):
        if s not in self._bulk_bundles:
            wf = ((self.cfg.frontend_tokens, self.cfg.frontend_dim)
                  if self.cfg.frontend else None)
            self._bulk_bundles[s] = build_prefill_step(
                self.cfg, self.mesh, self.scfg, batch=1, seq_len=s,
                with_frontend=wf, cache_len=self.srv.max_seq).fn
        return self._bulk_bundles[s]

    def _finish_fn(self, s: int):
        """Jitted scratch→ring-cache conversion, sharded like the slot
        writer's slot-cache input."""
        if s not in self._finish_fns:
            cfg, max_seq = self.cfg, self.srv.max_seq
            self._finish_fns[s] = jax.jit(
                lambda scr: scratch_to_cache(cfg, scr, cache_len=max_seq),
                out_shardings=self._slot_sh)
        return self._finish_fns[s]

    def _emit_first_token(self, i: int, req: Request, logits):
        """Sample the request's first decode token from the final prefill
        logits and move the slot to the decode phase.  ``first_token`` is
        stamped *here* — after the id has been computed and fetched, i.e.
        at the first decode token, not at prefill completion."""
        tok = int(jnp.argmax(logits[0], axis=-1))
        if req.first_token is None:
            # a re-admitted (recovered) request already stamped TTFT on
            # its genuine first token, pre-failure
            req.first_token = time.perf_counter()
        req.out_tokens.append(tok)
        req.phase = "decode"
        self._next_tok[i] = tok
        if (len(req.out_tokens) >= self.srv.max_new_tokens
                or tok == self.srv.eos_id):
            self._retire(i, req)

    def _prefill_tick(self):
        """Run at most one prefill chunk (or one bulk per-slot prefill) for
        the earliest-admitted slot still in the prefill phase — the
        admission work a server step interleaves between decode steps."""
        pending = [(req.rid, i, req) for i, req in enumerate(self.slots)
                   if req is not None and req.phase == "prefill"]
        if not pending:
            return
        _, i, req = min(pending)
        s = int(req.prompt.size)
        toks = jnp.asarray(req.prompt[None, :])

        if not self._chunkable:
            args = (self.params, toks)
            if self.cfg.frontend:
                args += (jnp.asarray(req.frontend_embeds[None, :]),)
            cache1, logits = self._bulk_fn(s)(*args)
            if self._paged:
                self._install_paged(i, req,
                                    cache_to_blocks(self.cfg, cache1,
                                                    self._blk))
            else:
                self.cache = self.writer.fn(self.cache, cache1,
                                            jnp.int32(i))
            self._emit_first_token(i, req, logits)
            return

        se = self._eff_len(s)
        cuts = prefill_chunk_cuts(se, chunk_len=self._eff_chunk)
        lo, hi = cuts[req._cursor]
        cfg = self.cfg
        if cfg.family == "encdec":
            # frames feed the encoder exactly once, on chunk 0
            n_fe = cfg.frontend_tokens if lo == 0 else None
            fe = (jnp.asarray(req.frontend_embeds[None, :])
                  if lo == 0 else None)
            tok_slice = toks[:, lo:hi]
        elif cfg.frontend:
            # vlm: frontend rows prefix the token rows of the scratch —
            # slice each exactly as the bulk concat lays them out
            ft = cfg.frontend_tokens
            n_fe = max(0, min(hi, ft) - lo) if lo < ft else None
            fe = (jnp.asarray(req.frontend_embeds[None, lo:min(hi, ft)])
                  if n_fe else None)
            if n_fe == 0:
                n_fe = None
            tok_slice = toks[:, max(0, lo - ft):max(0, hi - ft)]
        else:
            n_fe, fe = None, None
            tok_slice = toks[:, lo:hi]
        fn = self._chunk_bundle(se, lo, hi - lo, n_fe).fn
        args = (self.params, req._scratch, tok_slice)
        if n_fe is not None:
            args += (fe,)
        req._scratch, logits = fn(*args)
        req._cursor += 1
        if req._cursor < len(cuts):
            return                          # more chunks; decode proceeds
        if self._paged:
            blocks = self._blocks_fn(se)(req._scratch)
            req._scratch = None
            self._install_paged(i, req, blocks)
        else:
            cache1 = self._finish_fn(se)(req._scratch)
            req._scratch = None
            self.cache = self.writer.fn(self.cache, cache1, jnp.int32(i))
        self._emit_first_token(i, req, logits)

    def _retire(self, i: int, req: Request,
                now: Optional[float] = None):
        """The one retire path — finished, EOS, cancel, or timeout, at any
        phase.  Reclaims the unfinished admission scratch (a mid-prefill
        retire used to leak it), drops the slot's pool-block refs, and
        parks the row's block table so dead decode writes land in the
        slot's private parking block."""
        req.finished = time.perf_counter() if now is None else now
        req.phase = "done"
        req._scratch = None
        if self._paged and req._blocks:
            self.pool.release(req._blocks)
            req._blocks = []
            req._shared = 0
            self.cache = self._park_fn(self.cache, jnp.int32(i))
        self.done.append(req)
        self.slots[i] = None

    def cancel(self, rid: int) -> bool:
        """Abort a request wherever it is: queued → dropped; mid-prefill or
        decoding → retired through :meth:`_retire` (scratch and pool blocks
        reclaimed).  Returns whether the request was found in flight."""
        for q, req in enumerate(self.queue):
            if req.rid == rid:
                req.cancelled = True
                self.queue.pop(q)
                req.finished = time.perf_counter()
                req.phase = "done"
                self.done.append(req)
                return True
        for i, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                req.cancelled = True
                self._retire(i, req)
                return True
        return False

    # -- decode loop ----------------------------------------------------------

    def fail_decode_rank(self, rank: int, n_ranks: Optional[int] = None):
        """Single-rank form of :meth:`fail_decode_ranks`."""
        return self.fail_decode_ranks([rank], n_ranks)

    def fail_decode_ranks(self, ranks, n_ranks: Optional[int] = None):
        """Survive the loss of decode ranks ``ranks``: drain and re-admit.

        The pool's block ids are partitioned contiguously across
        ``n_ranks`` decode ranks (default: the mesh's data extent — the
        replicated rows that host pool shards).  Losing a rank loses its
        id span: the pool quarantines it (:meth:`BlockPool.fail_partition`,
        conservation holds throughout), prefix-cache entries pinning lost
        blocks are purged, and every in-flight slot whose table touches
        the span — or whose parking block died — is *drained*: blocks
        released, scratch dropped, and the request re-queued at the front
        with a **replay prompt** of ``prompt + tokens emitted so far``.
        Greedy decode is deterministic and prefill ≡ decode (asserted
        repo-wide), so the re-admitted continuation emits exactly the
        tokens the unfailed run would have; committed prefix blocks on
        surviving ranks come back copy-on-write through the prefix cache,
        so only the lost tail actually re-prefills.  Rows whose parking
        block died are retired from capacity (``_dead_slots``).

        In this single-process simulation the lost span's *array data* is
        physically intact — what the failure costs is re-prefill work and
        pool capacity, which is exactly what ``netmodel`` prices
        (``recovery_time``) and ``stats()`` reports.

        Several ranks lost in the same lease window are excluded in
        **one** sweep (:meth:`BlockPool.fail_partitions`): one free-list
        rebuild, one victim drain, one conservation check — never N
        sequential recoveries.
        """
        assert self._paged, \
            "decode-rank loss recovery needs the paged pool (paged=True)"
        if n_ranks is None:
            n_ranks = max(1, int(self.mesh.shape.get("data", 1)))
        dead = sorted({min(int(r), n_ranks - 1) for r in ranks})
        lost = self.pool.fail_partitions(dead, n_ranks)
        self._dead_slots |= {i for i in range(self.srv.max_batch)
                             if i in lost and i < self.pool.reserved}
        victims = [(req.rid, i, req) for i, req in enumerate(self.slots)
                   if req is not None
                   and (i in self._dead_slots or set(req._blocks) & lost)]
        drained = []
        for _, i, req in sorted(victims):
            if req._blocks:
                self.pool.release(req._blocks)
                req._blocks, req._shared = [], 0
            req._scratch = None
            req._cursor = 0
            if req.out_tokens:
                # replay = everything the request has already established;
                # re-prefilling it reproduces the decode state bit-exactly
                req.prompt = np.concatenate(
                    [req.prompt,
                     np.asarray(req.out_tokens, np.int32)]).astype(np.int32)
            req.phase = "queued"
            req._recovered = True
            self.slots[i] = None
            if i not in self._dead_slots:
                self.cache = self._park_fn(self.cache, jnp.int32(i))
            drained.append(req)
            self.recoveries += 1
        self.queue = drained + self.queue   # victims re-admit first
        self.pool.check_conservation()
        return len(drained)

    def admit_decode_rank(self, rank: int, n_ranks: Optional[int] = None):
        """Scale the pool back out: re-admit decode rank ``rank``'s span.

        The membership detector drives this at an epoch boundary when a
        joiner (a recovered victim, or fresh capacity) announces itself.
        Quarantined ids in the span return to the free list
        (:meth:`BlockPool.restore_partition` — admission capacity grows
        back by exactly that many blocks), and batch rows whose parking
        block was in the span rejoin capacity: they are re-parked (their
        tables point at their own parking block again) and removed from
        ``_dead_slots``.  Returns the number of block ids restored.
        """
        assert self._paged, \
            "decode-rank admission needs the paged pool (paged=True)"
        if n_ranks is None:
            n_ranks = max(1, int(self.mesh.shape.get("data", 1)))
        span = self.pool.restore_partition(min(int(rank), n_ranks - 1),
                                           n_ranks)
        revived = {i for i in self._dead_slots if i in span}
        for i in sorted(revived):
            self.cache = self._park_fn(self.cache, jnp.int32(i))
        self._dead_slots -= revived
        self.pool.check_conservation()
        return len(span)

    def step(self):
        """One scheduler tick: admit, run one prefill chunk, decode.

        With a :class:`~repro.runtime.faults.FaultPlan` attached, scripted
        kills are delivered here at host level (compiled steps never
        re-enter the conduit) and handled in place via
        :meth:`fail_decode_rank` — serving absorbs the loss instead of
        propagating it.  With a
        :class:`~repro.runtime.membership.MembershipService` attached,
        the *detector* decides instead: the plan only suppresses victims'
        leases, the service declares at a lease deadline, and its
        :class:`~repro.runtime.membership.MembershipEvent` drives
        :meth:`fail_decode_ranks` (one call per epoch bump, however many
        ranks died) and :meth:`admit_decode_rank` (scale-out joins)."""
        self._ticks += 1
        if self.membership is not None:
            ev = self.membership.on_step(self._ticks)
            if ev is not None:
                n = self.membership.n_ranks
                if ev.died:
                    self.fail_decode_ranks(ev.died, n_ranks=n)
                for r in ev.joined:
                    self.admit_decode_rank(r, n_ranks=n)
        elif self.fault_plan is not None:
            from repro.core.conduit import RankFailure
            try:
                self.fault_plan.on_step(self._ticks, "serve_step")
            except RankFailure as e:
                dead = e.rank if e.rank is not None else 0
                self.fault_plan.repair(dead)
                self.fail_decode_rank(dead)
        self._admit()
        self._prefill_tick()
        if not any(r is not None and r.phase == "decode"
                   for r in self.slots):
            return
        toks = jnp.asarray(self._next_tok)
        self.cache, ids = self.bundle.fn(self.params, self.cache, toks)
        choice = np.asarray(ids)            # ONE stacked host transfer
        now = time.perf_counter()
        for i, req in enumerate(self.slots):
            if req is None or req.phase != "decode":
                continue
            tok = int(choice[i])
            req.out_tokens.append(tok)
            self._next_tok[i] = tok
            if (len(req.out_tokens) >= self.srv.max_new_tokens
                    or tok == self.srv.eos_id):
                self._retire(i, req, now)

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # -- metrics ---------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        lat = [r.finished - r.submitted for r in self.done if r.finished]
        ttft = [r.first_token - r.submitted for r in self.done
                if r.first_token]
        itl = [(r.finished - r.first_token) / (len(r.out_tokens) - 1)
               for r in self.done
               if r.finished and r.first_token and len(r.out_tokens) > 1]
        toks = sum(len(r.out_tokens) for r in self.done)
        wall = (max(r.finished for r in self.done)
                - min(r.submitted for r in self.done)) if self.done else 0.0
        out = {
            "requests": len(self.done),
            "tokens": toks,
            "throughput_tok_s": toks / wall if wall else 0.0,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
            "mean_itl_s": float(np.mean(itl)) if itl else 0.0,
            # admission mode is part of the serving surface: no arch may
            # fall back to bulk without this signal (and a build warning)
            "admission_mode": (f"chunked({self._eff_chunk})"
                               if self._chunkable else "bulk"),
            "admission_fallback": self._fallback_reason,
        }
        if self._paged:
            out.update({
                "prefix_hits": float(self.prefix_hits),
                "prefix_misses": float(self.prefix_misses),
                "pool_evictions": float(self.pool.evictions),
                "pool_free_blocks": float(self.pool.free_blocks),
                "recoveries": float(self.recoveries),
                "reprefilled_tokens": float(self.reprefilled_tokens),
                "lost_blocks": float(self.pool.lost_blocks),
                "quarantined_blocks": float(self.pool.quarantined_blocks),
                "dead_slots": float(len(self._dead_slots)),
            })
        return out


def drive_arrivals(server: Server, prompts, every: int,
                   max_steps: int = 10_000) -> int:
    """Run ``server`` under synthetic arrivals: one prompt up front, one
    more every ``every`` scheduler ticks, until the queue drains.  Each
    item is a prompt array, or a ``(prompt, frontend_embeds)`` pair for
    frontend archs (vlm patches / encdec frames).  The one arrival loop
    both the CLI (``launch/serve.py --arrive-every``) and the measured
    benchmark section (``benchmarks/serve_bench.py``) drive, so they
    always measure the same workload.  Returns the tick count.
    """
    def _submit(item):
        if isinstance(item, tuple):
            server.submit(item[0], item[1])
        else:
            server.submit(item)

    pending = list(prompts)
    _submit(pending.pop(0))
    steps = 0
    while ((pending or server.queue
            or any(s is not None for s in server.slots))
           and steps < max_steps):
        server.step()
        steps += 1
        if pending and steps % max(1, every) == 0:
            _submit(pending.pop(0))
    return steps
