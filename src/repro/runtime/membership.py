"""Live membership: heartbeat leases, miss-count detection, epochs.

PR 9 made recovery *possible*; this module makes it *live*.  Instead of a
scripted ``FaultPlan`` declaring deaths, every rank publishes a **lease
counter** into a PGAS heartbeat segment (``pgas.HeartbeatSegment``) via
short Active Messages, and a deterministic miss-count detector — a
phi-accrual detector quantized to the host-step clock, "phi-accrual-lite"
— declares a rank dead after **K consecutive missed lease deadlines**.
The scripted plan survives as one detector *input*: ``kill_rank`` only
suppresses the victim's lease publishes (``FaultPlan.lease_suppressed``),
``delay_am`` only lags heartbeat arrivals, and the detector does all the
declaring.  Every decision is a function of (events, step, call counts),
so chaos runs stay bit-reproducible.

**Epochs.**  Each membership change — deaths, joins, or both — bumps a
single **epoch** counter.  The service installs itself as the conduit
epoch provider (``conduit.install_epoch_provider``); a conduit or AM wire
pinned at a stale epoch (:meth:`~repro.core.conduit.Conduit.at_epoch`)
raises :class:`~repro.core.conduit.StaleEpoch` instead of touching the
network, so in-flight work from a dead view can never complete into a new
one.  All ranks that miss the same deadline are batched into **one**
epoch bump — recovery re-forms conduits once, not N times — and pending
joins admitted at that deadline ride the same view change.

**Clock model.**  The detector runs on the host-step clock: publishes and
deadline checks happen at steps where ``step % lease_period == 0``
(publish first, then check, so a healthy same-step publish is always
fresh).  ``step_time_s`` maps scripted ``delay_am`` jitter (seconds) onto
arrival lag (steps).  Worst-case detection latency is strictly below
``lease_period × (k_misses + 1)`` steps — the bound the bench gate holds
(``core/netmodel.detection_latency``) — and a delivery jitter of ``d``
seconds causes ``ceil(d / lease_period_s)`` consecutive misses
(``core/netmodel.heartbeat_misses``), so any jitter below
``(k_misses − 1) × lease_period_s`` can never false-positive.

The host-side mirror in :class:`MembershipService` is the deterministic
source of truth; :func:`build_heartbeat_wire` builds the actual AM wire
image (lease PUTs + join announcements into every peer's segment), which
the tests validate against the mirror.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.conduit import (Conduit, RankFailure, StaleEpoch,
                                clear_epoch_provider, clear_failure_hook,
                                install_epoch_provider, install_failure_hook)
from repro.runtime.faults import FaultPlan


@dataclasses.dataclass(frozen=True)
class LeaseConfig:
    """Detector tuning: how often leases publish, how many misses kill.

    ``lease_period`` — host steps between lease publishes (and deadline
    checks).  ``k_misses`` — consecutive missed deadlines before a rank
    is declared dead.  ``step_time_s`` — nominal wall seconds per host
    step, the bridge between scripted ``delay_am`` jitter (seconds) and
    the step-quantized detector; also what the netmodel detection rows
    price against.
    """

    lease_period: int = 1
    k_misses: int = 3
    step_time_s: float = 1e-3

    def __post_init__(self):
        """Validate the detector parameters."""
        if self.lease_period < 1:
            raise ValueError(f"lease_period must be >= 1, "
                             f"got {self.lease_period}")
        if self.k_misses < 1:
            raise ValueError(f"k_misses must be >= 1, got {self.k_misses}")
        if self.step_time_s <= 0:
            raise ValueError(f"step_time_s must be > 0, "
                             f"got {self.step_time_s}")


@dataclasses.dataclass(frozen=True)
class MembershipView:
    """One immutable membership version: ``epoch`` plus the live ranks."""

    epoch: int
    ranks: Tuple[int, ...]

    def contains(self, rank: int) -> bool:
        """Whether ``rank`` is live in this view."""
        return rank in self.ranks


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One epoch bump: who ``died`` and who ``joined`` at ``step``.

    Deaths and joins landing at the same deadline share one event (and
    one epoch) by construction — the batching invariant the property
    suite holds.
    """

    step: int
    epoch: int
    died: Tuple[int, ...] = ()
    joined: Tuple[int, ...] = ()


class MembershipService:
    """The live membership: lease table, miss-count detector, epoch source.

    Drive it with :meth:`on_step` once per host step (the same clock
    ``FaultPlan.on_step`` rides); it returns a :class:`MembershipEvent`
    when the view changed, ``None`` otherwise.  Install it
    (:meth:`install` / ``with service:``) to become both the conduit
    failure hook (delegating transient ``drop_op``/``delay_am`` to the
    wrapped plan) and the conduit **epoch provider** — epoch-pinned
    conduits and AM wires then raise ``StaleEpoch`` the moment the view
    they were built against is superseded.

    ``n_ranks`` is the initial rank universe ``[0, n_ranks)``; ranks can
    die, rejoin (:meth:`schedule_join`), or join fresh with a new id (the
    training scale-out path).  All decisions are deterministic functions
    of (events, step): no wall clocks, no RNG.
    """

    def __init__(self, n_ranks: int, cfg: LeaseConfig = LeaseConfig(),
                 fault_plan: Optional[FaultPlan] = None):
        """Start with ranks ``[0, n_ranks)`` live at epoch 0.

        ``fault_plan`` (optional) is the scripted chaos input: its kills
        suppress leases, its ``delay_am`` lags arrivals, its transient
        drops pass through the failure hook.  A plan in ``deliver="raise"``
        mode would double-deliver kills, so lease mode is required.
        """
        if n_ranks < 1:
            raise ValueError(f"n_ranks must be >= 1, got {n_ranks}")
        if fault_plan is not None and fault_plan.deliver != "lease":
            raise ValueError(
                "MembershipService needs FaultPlan(deliver='lease'): in "
                "'raise' mode the script would declare deaths itself")
        self.n_ranks = int(n_ranks)
        self.cfg = cfg
        self.fault_plan = fault_plan
        self._epoch = 0
        self._ranks: Tuple[int, ...] = tuple(range(self.n_ranks))
        self._step = -1                      # last processed host step
        self._lease: Dict[int, int] = {r: 0 for r in self._ranks}
        self._last_arrival: Dict[int, int] = {r: 0 for r in self._ranks}
        self._misses: Dict[int, int] = {r: 0 for r in self._ranks}
        self._arrivals: List[Tuple[int, int, int]] = []  # (arrive, rank, lease)
        self._pending_joins: List[Tuple[int, int]] = []  # (rank, at_step)
        self.events: List[MembershipEvent] = []
        self.log: List[Tuple[int, str, str]] = []

    # -- views ---------------------------------------------------------------

    @property
    def epoch(self) -> int:
        """The current membership epoch (monotone, starts at 0)."""
        return self._epoch

    def view(self) -> MembershipView:
        """The current immutable :class:`MembershipView`."""
        return MembershipView(self._epoch, self._ranks)

    def alive(self, rank: int) -> bool:
        """Whether ``rank`` is in the current view."""
        return rank in self._ranks

    def leases(self) -> Dict[int, int]:
        """Freshest lease counter heard per live rank (the host mirror of
        the PGAS heartbeat segment's lease slots)."""
        return dict(self._lease)

    def bind(self, conduit: Conduit) -> Conduit:
        """Pin ``conduit`` to the current epoch (:meth:`Conduit.at_epoch`):
        it raises ``StaleEpoch`` once this view is superseded."""
        return conduit.at_epoch(self._epoch)

    # -- joins ---------------------------------------------------------------

    def schedule_join(self, rank: int, *, at_step: int) -> None:
        """Script a join announcement for ``rank`` at host step
        ``at_step`` — the deterministic analogue of a new node's JOIN AM
        arriving.  Admission happens at the first deadline ≥ the
        announcement; a rank already live by then is dropped silently."""
        if rank < 0:
            raise ValueError(f"rank must be >= 0, got {rank}")
        self._pending_joins.append((int(rank), int(at_step)))

    def announce_join(self, rank: int) -> None:
        """A join announcement arriving *now* (next processed step)."""
        self.schedule_join(rank, at_step=self._step + 1)

    # -- the detector --------------------------------------------------------

    def on_step(self, step: int) -> Optional[MembershipEvent]:
        """Advance the detector to host ``step``; returns the (last)
        :class:`MembershipEvent` if the view changed, else ``None``.

        Every intermediate step is processed exactly once, so the result
        is independent of how the caller paces its calls — the property
        that keeps chaos runs bit-reproducible.
        """
        step = int(step)
        out: Optional[MembershipEvent] = None
        while self._step < step:
            self._step += 1
            ev = self._tick(self._step)
            if ev is not None:
                out = ev
        return out

    def _delay_steps(self, step: int) -> int:
        """Scripted AM jitter at ``step``, quantized to host steps."""
        if self.fault_plan is None:
            return 0
        return int(self.fault_plan.am_delay_at(step)
                   // self.cfg.step_time_s)

    def _suppressed(self, rank: int, step: int) -> bool:
        """Whether ``rank``'s publish at ``step`` is scripted away."""
        if self.fault_plan is None:
            return False
        return self.fault_plan.lease_suppressed(rank, step)

    def _tick(self, s: int) -> Optional[MembershipEvent]:
        """Process exactly one host step: publish → deliver → deadline."""
        p, k = self.cfg.lease_period, self.cfg.k_misses
        if self.fault_plan is not None:
            self.fault_plan.tick(s)

        # publish: each live, unsuppressed rank sends lease+1; scripted AM
        # jitter lags the arrival (the detector sees it `delay` steps late)
        if s % p == 0:
            delay = self._delay_steps(s)
            for r in self._ranks:
                if not self._suppressed(r, s):
                    self._arrivals.append((s + delay, r,
                                           self._lease.get(r, 0) + 1))

        # deliver everything due by now (in send order — deterministic)
        due = [a for a in self._arrivals if a[0] <= s]
        if due:
            self._arrivals = [a for a in self._arrivals if a[0] > s]
            for arrive, r, lease in due:
                if r in self._ranks:          # non-members' leases ignored
                    self._last_arrival[r] = max(self._last_arrival[r],
                                                arrive)
                    self._lease[r] = max(self._lease[r], lease)

        # deadline: a rank is fresh iff a lease arrived in (s-p, s]
        if s % p != 0 or s == 0:
            return None
        died: List[int] = []
        for r in self._ranks:
            if self._last_arrival.get(r, -1) > s - p:
                self._misses[r] = 0
            else:
                self._misses[r] += 1
                if self._misses[r] >= k:
                    died.append(r)
        joined = sorted({r for (r, at) in self._pending_joins
                         if at <= s and r not in self._ranks
                         and r not in died})
        if not died and not joined:
            return None
        return self._view_change(s, sorted(died), joined)

    def _view_change(self, s: int, died: List[int],
                     joined: List[int]) -> MembershipEvent:
        """One epoch bump for the whole batch of deaths + joins."""
        self._epoch += 1
        ranks = [r for r in self._ranks if r not in died]
        for r in died:
            self._lease.pop(r, None)
            self._last_arrival.pop(r, None)
            self._misses.pop(r, None)
            # the runtime will exclude the rank; the script has nothing
            # left to suppress (mirrors the legacy repair-on-recovery)
            if self.fault_plan is not None:
                self.fault_plan.repair(r)
        for r in joined:
            ranks.append(r)
            self._lease[r] = 0
            self._last_arrival[r] = s        # admission grace: fresh now
            self._misses[r] = 0
        self._pending_joins = [(r, at) for (r, at) in self._pending_joins
                               if r not in joined and at > s]
        self._ranks = tuple(sorted(ranks))
        ev = MembershipEvent(step=s, epoch=self._epoch,
                             died=tuple(died), joined=tuple(joined))
        self.events.append(ev)
        self.log.append((s, "epoch",
                         f"{self._epoch}: died={died} joined={joined}"))
        return ev

    # -- failure declaration for the runtime loops ---------------------------

    def failure_for(self, ev: MembershipEvent) -> RankFailure:
        """The typed exception a runtime loop raises for ``ev``'s deaths —
        one :class:`RankFailure` carrying the whole batch in ``.ranks``."""
        return RankFailure(min(ev.died), "membership",
                           f"K={self.cfg.k_misses} missed leases, "
                           f"epoch {ev.epoch}", ranks=ev.died)

    # -- conduit hook + epoch provider ---------------------------------------

    def __call__(self, op: str, axis: str) -> None:
        """The conduit failure probe: transient faults delegate to the
        wrapped plan (which, in lease mode, never raises kills — an
        undetected death stays invisible to the wire until the detector
        declares it and the epoch check takes over)."""
        if self.fault_plan is not None:
            self.fault_plan(op, axis)

    def install(self) -> "MembershipService":
        """Register as both conduit failure hook and epoch provider."""
        install_failure_hook(self)
        install_epoch_provider(lambda: self._epoch)
        return self

    def uninstall(self) -> None:
        """Deregister the failure hook and epoch provider."""
        clear_failure_hook()
        clear_epoch_provider()

    def __enter__(self) -> "MembershipService":
        """Context manager: install on entry."""
        return self.install()

    def __exit__(self, *exc) -> None:
        """Context manager: uninstall on exit (exceptions propagate)."""
        self.uninstall()


# ---------------------------------------------------------------------------
# The AM wire: lease PUTs + join announcements into every peer's segment
# ---------------------------------------------------------------------------


def register_heartbeat_handlers(registry, seg) -> Tuple[int, int]:
    """Register the HEARTBEAT and JOIN request handlers on ``registry``.

    HEARTBEAT: ``args = (rank, lease)`` — deposit ``lease`` at the
    sender's lease slot in the local :class:`~repro.core.pgas.HeartbeatSegment`.
    JOIN: ``args = (rank,)`` — set the sender's join flag.  Returns the
    two opcodes.
    """
    import jax.numpy as jnp
    from jax import lax

    from repro.core.am import MAX_ARGS

    base = seg.symbol.offset
    n = seg.n_ranks

    def _heartbeat(heap, args, payload):
        rank, lease = args[0], args[1]
        slot = jnp.asarray(base, jnp.int32) + rank
        heap = lax.dynamic_update_slice(
            heap, lease.astype(heap.dtype)[None], (slot,))
        return (heap, jnp.int32(0), jnp.zeros((MAX_ARGS,), jnp.int32),
                jnp.zeros_like(payload))

    def _join(heap, args, payload):
        rank = args[0]
        slot = jnp.asarray(base + n, jnp.int32) + rank
        heap = lax.dynamic_update_slice(
            heap, jnp.ones((1,), heap.dtype), (slot,))
        return (heap, jnp.int32(0), jnp.zeros((MAX_ARGS,), jnp.int32),
                jnp.zeros_like(payload))

    hb_op = registry.register_request("HEARTBEAT", _heartbeat)
    join_op = registry.register_request("JOIN", _join)
    return hb_op, join_op


def build_heartbeat_wire(gas, registry=None):
    """Build the jitted heartbeat publishers over ``gas``'s PGAS axis.

    Returns ``(seg, publish, announce)``:

    * ``seg`` — the :class:`~repro.core.pgas.HeartbeatSegment` (allocated
      on demand);
    * ``publish(heap_global, leases)`` — every rank writes its own lease
      locally and PUTs ``(rank, lease)`` into every peer's lease slot via
      ``n−1`` ring-shifted short AMs (``leases`` is the per-rank counter
      vector, sharded over the axis; a suppressed rank simply publishes a
      stale counter — exactly what the detector's host mirror models);
    * ``announce(joiner)(heap_global)`` — rank ``joiner`` sets its join
      flag on every rank's segment (its JOIN announcement).

    The wire is the hardware image of :class:`MembershipService`'s host
    mirror; ``tests/test_membership.py`` asserts the two agree.
    """
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core.am import HandlerRegistry, am_request_short, make_args

    if registry is None:
        registry = HandlerRegistry()
    seg = gas.heartbeat_segment()
    hb_op, join_op = register_heartbeat_handlers(registry, seg)
    axis, n = gas.axis, gas.n_ranks
    base = seg.symbol.offset

    def _publish(heap, lease):
        my = lax.axis_index(axis)
        # own slot: a rank always hears itself
        heap = lax.dynamic_update_slice(
            heap, lease.astype(heap.dtype),
            (jnp.asarray(base, jnp.int32) + my,))
        args = make_args(my, lease[0])
        for shift in range(1, n):
            perm = [(i, (i + shift) % n) for i in range(n)]
            heap = am_request_short(registry, heap, hb_op, args,
                                    axis=axis, perm=perm)
        return heap

    publish = gas.run(_publish, extra_in_specs=(P(axis),))

    def announce(joiner: int):
        """Jitted JOIN announcement from rank ``joiner`` to every peer."""
        def _ann(heap):
            my = lax.axis_index(axis)
            flag = jnp.asarray(base + n + joiner, jnp.int32)
            own = lax.dynamic_update_slice(
                heap, jnp.ones((1,), heap.dtype), (flag,))
            heap = jnp.where(my == joiner, own, heap)
            args = make_args(jnp.int32(joiner))
            for shift in range(1, n):
                perm = [(joiner, (joiner + shift) % n)]
                heap = am_request_short(registry, heap, join_op, args,
                                        axis=axis, perm=perm)
            return heap
        return gas.run(_ann)

    return seg, publish, announce


__all__ = [
    "LeaseConfig", "MembershipView", "MembershipEvent", "MembershipService",
    "StaleEpoch", "register_heartbeat_handlers", "build_heartbeat_wire",
]
