"""Fault-tolerant training loop.

Failure model and responses (DESIGN §6):

  device/host loss     -> catch, ``elastic.remesh`` excluding dead devices,
                          rebuild the step on the new mesh, restore the last
                          checkpoint resharded, resume (data pipeline is
                          stateless — nothing else to recover)
  straggler            -> per-step wall-clock watchdog; a step slower than
                          ``straggler_factor ×`` the trailing median is
                          flagged; after ``straggler_patience`` consecutive
                          flags the offending host set is treated as failed
                          and the elastic path runs (in simulation we log)
  preemption (SIGTERM) -> handler requests a checkpoint at the next step
                          boundary, then exits cleanly
  periodic             -> atomic checkpoint every ``ckpt.interval`` steps
                          (write-temp + fsync + rename; see checkpoint/)

The loop is deliberately synchronous-SPMD (one jit per step): fault
tolerance lives *around* the step, not inside it, exactly like the paper
keeps the host off the FPGA's critical path.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.conduit import RankFailure
from repro.data.pipeline import SyntheticLM
from repro.dist.bucketing import DEFAULT_BUCKET_BYTES
from repro.dist.steps import (StepConfig, build_init, build_train_step,
                              refit_step_config)
from repro.runtime.elastic import ElasticRuntime


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 300
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_interval: int = 100
    keep_last: int = 3
    log_interval: int = 10
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, scfg: StepConfig, tcfg: TrainerConfig,
                 data: SyntheticLM, mesh=None,
                 log_fn: Callable[[str], None] = print,
                 fault_plan=None, membership=None):
        self.cfg, self.scfg, self.tcfg = cfg, scfg, tcfg
        self.data = data
        self.log = log_fn
        self.mesh = mesh
        self.fault_plan = fault_plan
        # live detector path: a MembershipService polled at every host
        # step — its declarations (not scripted raises) drive recovery
        self.membership = membership
        self.elastic: Optional[ElasticRuntime] = None
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, tcfg.ckpt_interval,
                                      tcfg.keep_last)
        self._preempted = False
        self._step_times: List[float] = []
        self._straggler_strikes = 0
        self.history: List[Dict[str, float]] = []

    # -- preemption ----------------------------------------------------------

    def install_signal_handler(self):
        def _handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, _handler)

    # -- build / restore -----------------------------------------------------

    def _build(self, mesh):
        from repro.data.pipeline import batch_specs

        dcfg = self.data.cfg
        bshape = batch_specs(dcfg.seq_len - 1, dcfg.global_batch,
                             dcfg.vocab_size)
        self.bundle = build_train_step(self.cfg, mesh, self.scfg, bshape)
        self.init_fn, (self.pspecs, self.ospecs) = build_init(
            self.cfg, mesh, self.scfg)

    def _state_shardings(self, mesh):
        from repro.dist.sharding import to_shardings
        return (to_shardings(mesh, self.pspecs),
                to_shardings(mesh, self.ospecs))

    def _restore_or_init(self, mesh):
        self._build(mesh)
        psh, osh = self._state_shardings(mesh)
        template = (self.bundle.aux["params_shape"],
                    self.bundle.aux["opt_shape"])
        got = self.ckpt.restore_or_none(template, (psh, osh))
        if got is not None:
            (params, opt), manifest = got
            start = manifest["step"]
            self.log(f"[trainer] restored step {start} from {self.ckpt.directory}")
            return params, opt, start
        params, opt = self.init_fn(jax.random.PRNGKey(self.tcfg.seed))
        return params, opt, 0

    # -- straggler watchdog ---------------------------------------------------

    def _watch_step_time(self, dt: float) -> bool:
        """Returns True when the straggler budget is exhausted."""
        self._step_times.append(dt)
        window = self._step_times[-50:]
        if len(window) < 5:
            return False
        med = statistics.median(window[:-1])
        if dt > self.tcfg.straggler_factor * med:
            self._straggler_strikes += 1
            self.log(f"[watchdog] slow step {dt*1e3:.1f} ms vs median "
                     f"{med*1e3:.1f} ms (strike {self._straggler_strikes})")
        else:
            self._straggler_strikes = 0
        return self._straggler_strikes >= self.tcfg.straggler_patience

    # -- main loop -------------------------------------------------------------

    def train(self, mesh=None, on_step: Optional[Callable] = None):
        mesh = mesh or self.mesh
        assert mesh is not None, "Trainer needs a mesh"
        params, opt, start = self._restore_or_init(mesh)
        step = start
        n_failures = 0

        while step < self.tcfg.total_steps:
            if self.membership is not None:
                ev = self.membership.on_step(step)
                if ev is not None and ev.died:
                    n_failures += 1
                    failure = self.membership.failure_for(ev)
                    self.log(f"[trainer] step {step}: membership epoch "
                             f"{ev.epoch} declared ranks {list(ev.died)} "
                             f"dead; elastic recovery #{n_failures}")
                    mesh = self._recover_mesh(mesh, failure)
                    params, opt, step = self._restore_or_init(mesh)
                    continue
                if ev is not None and ev.joined:
                    self.log(f"[trainer] step {step}: membership epoch "
                             f"{ev.epoch} admitted ranks "
                             f"{list(ev.joined)}; scaling out")
                    mesh = self._scale_out(mesh)
                    params, opt, step = self._restore_or_init(mesh)
                    continue
            batch = self.data.global_batch(step)
            t0 = time.perf_counter()
            try:
                if self.fault_plan is not None:
                    # compiled steps never re-enter the conduit: scripted
                    # kills must be delivered at host-step level too
                    self.fault_plan.on_step(step, "train_step")
                params, opt, metrics = self.bundle.fn(
                    params, opt, batch, jnp.int32(step))
                jax.block_until_ready(metrics["loss"])
            except Exception as e:      # device loss / comm failure
                n_failures += 1
                self.log(f"[trainer] step {step} failed ({type(e).__name__}: "
                         f"{e}); elastic recovery #{n_failures}")
                mesh = self._recover_mesh(mesh, e)
                params, opt, step = self._restore_or_init(mesh)
                continue
            dt = time.perf_counter() - t0

            step += 1
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["step_time_s"] = dt
            self.history.append(m)
            if on_step:
                on_step(step, m)
            if step % self.tcfg.log_interval == 0:
                self.log(f"[trainer] step {step} loss {m['loss']:.4f} "
                         f"ce {m['ce']:.4f} gnorm {m['grad_norm']:.2f} "
                         f"lr {m['lr']:.2e} {dt*1e3:.0f} ms")

            if self._watch_step_time(dt):
                self.log("[watchdog] straggler budget exhausted — would "
                         "trigger elastic re-mesh on a real deployment")
                self._straggler_strikes = 0

            if self.ckpt.should_save(step) or self._preempted:
                path = self.ckpt.save(step, (params, opt),
                                      extra={"loss": m["loss"]})
                self.log(f"[trainer] checkpoint -> {path}")
                if self._preempted:
                    self.log("[trainer] preemption checkpoint complete; exiting")
                    return params, opt, step

        # final checkpoint
        self.ckpt.save(step, (params, opt),
                       extra={"loss": self.history[-1]["loss"]
                              if self.history else None})
        return params, opt, step

    def _recover_mesh(self, mesh, failure: Optional[Exception] = None):
        """Rebuild the mesh from the devices that still respond.

        A typed :class:`~repro.core.conduit.RankFailure` names the dead
        member; the :class:`~repro.runtime.elastic.ElasticRuntime` then
        excludes it, re-forms the conduits, and scales grad accumulation
        so the global batch survives the data-axis shrink (the rebuilt
        step bundle picks the new ``microbatches`` up from ``self.scfg``).
        Untyped failures keep the legacy behavior: rebuild over whatever
        ``jax.devices()`` still answers.
        """
        model = mesh.shape.get("model", 1)
        if self.elastic is None:
            self.elastic = ElasticRuntime(
                model=model, axis_names=tuple(mesh.axis_names),
                devices=list(mesh.devices.flat),
                fault_plan=self.fault_plan)
        if isinstance(failure, RankFailure):
            report = self.elastic.on_failure(
                failure, microbatches=self.scfg.microbatches,
                grad_bucket_bytes=self.scfg.grad_bucket_bytes
                or DEFAULT_BUCKET_BYTES)
            old_data = dict(report.old_shape).get("data", 1)
            new_data = dict(report.new_shape).get("data", 1)
            if new_data != old_data:
                self.log(f"[trainer] data axis {old_data} -> {new_data}: "
                         f"grad accumulation x{old_data // new_data} "
                         f"to hold the global batch")
                self.scfg = refit_step_config(self.scfg, old_data, new_data)
            return self.elastic.mesh()
        return self.elastic.mesh()

    def _scale_out(self, mesh, device=None):
        """Admit a joining device and re-expand the data axis.

        The inverse of :meth:`_recover_mesh`: the
        :class:`~repro.runtime.elastic.ElasticRuntime` joins the device
        (the first spare when ``None``), re-forms conduits over the grown
        axis, and grad accumulation *divides* so the global batch stays
        constant.  When no spare device exists (a logical membership
        wider than the host's device pool), the mesh is left unchanged —
        the join is a pool-level event only.
        """
        model = mesh.shape.get("model", 1)
        if self.elastic is None:
            self.elastic = ElasticRuntime(
                model=model, axis_names=tuple(mesh.axis_names),
                devices=list(mesh.devices.flat),
                fault_plan=self.fault_plan)
        try:
            report = self.elastic.on_join(
                device, microbatches=self.scfg.microbatches,
                grad_bucket_bytes=self.scfg.grad_bucket_bytes
                or DEFAULT_BUCKET_BYTES)
        except RuntimeError as e:
            self.log(f"[trainer] scale-out skipped: {e}")
            return self.elastic.mesh()
        old_data = dict(report.old_shape).get("data", 1)
        new_data = dict(report.new_shape).get("data", 1)
        if new_data != old_data:
            self.log(f"[trainer] data axis {old_data} -> {new_data}: "
                     f"grad accumulation /{new_data // old_data} "
                     f"to hold the global batch")
            self.scfg = refit_step_config(self.scfg, old_data, new_data)
        return self.elastic.mesh()
