"""Roofline analysis from compiled dry-run artifacts."""

from repro.analysis.roofline import (
    HW,
    TPU_V5E,
    CollectiveStats,
    RooflineReport,
    parse_collectives,
    roofline_from_compiled,
)

__all__ = ["HW", "TPU_V5E", "CollectiveStats", "RooflineReport",
           "parse_collectives", "roofline_from_compiled"]
