"""Hillclimb profiling aid: rank individual collective/dot ops in a
partitioned module by loop-multiplied cost, with source attribution from
the op metadata (this is the dry-run's 'profiler')."""

from __future__ import annotations

import re
from typing import List

from repro.analysis.hlo_cost import (
    _collective_operand_bytes, _dot_flops, _trip_count, _COLLECTIVES,
    parse_module)

_META_RE = re.compile(r'op_name="([^"]+)"')


def top_ops(text: str, kind: str = "collective", n: int = 20) -> List[dict]:
    """kind: 'collective' (bytes) or 'dot' (flops)."""
    comps, entry = parse_module(text)
    out = []

    def visit(cname, mult, depth=0):
        comp = comps.get(cname)
        if comp is None or depth > 64:
            return
        for op in comp.ops:
            base = op.opcode.split("-start")[0]
            if kind == "collective" and base in _COLLECTIVES:
                b = _collective_operand_bytes(op, comp)
                meta = _META_RE.search(op.line)
                out.append({"op": base, "bytes": b, "mult": mult,
                            "total": b * mult,
                            "shape": op.result_type[:60],
                            "path": (meta.group(1)[-120:] if meta else "")})
            elif kind == "dot" and op.opcode == "dot":
                f = _dot_flops(op, comp)
                meta = _META_RE.search(op.line)
                out.append({"op": "dot", "flops": f, "mult": mult,
                            "total": f * mult,
                            "shape": op.result_type[:60],
                            "path": (meta.group(1)[-120:] if meta else "")})
            if op.opcode == "while":
                cm = re.search(r"condition=(%?[\w\.\-]+)", op.line)
                bm = re.search(r"body=(%?[\w\.\-]+)", op.line)
                if cm and bm:
                    trips = _trip_count(op.line,
                                        comps.get(cm.group(1).lstrip("%")))
                    visit(bm.group(1).lstrip("%"), mult * trips, depth + 1)
            elif op.opcode == "fusion":
                m = re.search(r"calls=(%?[\w\.\-]+)", op.line)
                if m:
                    visit(m.group(1).lstrip("%"), mult, depth + 1)
            elif op.opcode in ("call", "custom-call"):
                m = re.search(r"to_apply=(%?[\w\.\-]+)", op.line)
                if m:
                    visit(m.group(1).lstrip("%"), mult, depth + 1)

    if entry:
        visit(entry, 1.0)
    out.sort(key=lambda d: -d["total"])
    return out[:n]


def print_top(text: str, kind: str = "collective", n: int = 15):
    for d in top_ops(text, kind, n):
        val = d["total"]
        unit = "B" if kind == "collective" else "F"
        print(f"  {d['op']:20s} {val:.3e}{unit} (x{d['mult']:.0f})  "
              f"{d['shape']}")
        if d["path"]:
            print(f"      {d['path']}")
