"""Three-term roofline from the compiled SPMD module (ROOFLINE ANALYSIS spec).

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are NOT in
cost_analysis, so we parse ``compiled.as_text()`` (the *post-partitioning*
module — per-device operand shapes) and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Scope note: the partitioned module is one device's program, so parsed sizes
are per-device; the task formula's ``collective_bytes`` is the global sum,
i.e. per-device × chips — the ``chips`` factors cancel and the term equals
``per_device_coll_bytes / link_bw`` (same for FLOPs when cost_analysis
reports the partitioned program).  ``calibrate_cost_scope()`` detects which
scope cost_analysis reports on this backend and the loader normalizes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# matches e.g. bf16[256,4096]{1,0} or f32[] — dtype + dims
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in a (partitioned) module.

    Works on the full-form HLO text where operand types are printed inline:
    ``%ag = bf16[a,b] all-gather(bf16[c,d] %x), ...`` — we sum the operand
    type tokens inside the call parens (not the result type).
    """
    bytes_by: Dict[str, int] = {}
    count_by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # operand section: from the opening paren of the call to the
        # matching close — approximate with "rest of line up to '), '".
        start = m.end()
        depth = 1
        i = start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operands = line[start: i - 1]
        total = sum(_shape_bytes(d, s) for d, s in _TYPE_RE.findall(operands))
        if total == 0:
            # fall back to the result type (some dumps omit operand types)
            head = line[: m.start()]
            tys = _TYPE_RE.findall(head)
            total = sum(_shape_bytes(d, s) for d, s in tys)
        bytes_by[op] = bytes_by.get(op, 0) + total
        count_by[op] = count_by.get(op, 0) + 1
    return CollectiveStats(bytes_by, count_by)


# ---------------------------------------------------------------------------
# hardware + report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float      # bf16 FLOP/s per chip
    hbm_bw: float          # bytes/s per chip
    link_bw: float         # bytes/s per ICI link direction
    hbm_bytes: float       # capacity per chip


TPU_V5E = HW(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
             link_bw=50e9, hbm_bytes=16e9)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities (partitioned-module scope)
    flops: float
    hbm_bytes_accessed: float
    coll_bytes: float
    coll_by_op: Dict[str, int]
    coll_count: int
    # derived
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # 6·N·D global
    useful_ratio: float         # model_flops / (flops × chips)
    mem_per_device: Optional[Dict[str, float]] = None
    note: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_from_parts(
    *, arch: str, shape: str, mesh: str, chips: int,
    per_device_flops: float, per_device_bytes: float,
    coll: CollectiveStats, model_flops: float,
    hw: HW = TPU_V5E, mem: Optional[Dict[str, float]] = None,
    note: str = "",
) -> RooflineReport:
    compute_s = per_device_flops / hw.peak_flops
    memory_s = per_device_bytes / hw.hbm_bw
    collective_s = coll.total_bytes / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = (model_flops / (per_device_flops * chips)
              if per_device_flops else 0.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops=per_device_flops, hbm_bytes_accessed=per_device_bytes,
        coll_bytes=coll.total_bytes, coll_by_op=coll.bytes_by_op,
        coll_count=coll.total_count,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        mem_per_device=mem, note=note,
    )


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh_desc: str,
                           chips: int, model_flops: float,
                           hw: HW = TPU_V5E, note: str = "") -> RooflineReport:
    """Loop-aware terms from the partitioned module text (analysis.hlo_cost);
    ``cost_analysis``/``memory_analysis`` retained for capacity checking.
    (XLA's cost_analysis counts while bodies once — see hlo_cost docstring.)
    """
    from repro.analysis.hlo_cost import summarize

    s = summarize(compiled.as_text())
    flops = float(s.flops)
    bytes_accessed = float(s.bytes)
    coll = CollectiveStats(
        {k: int(v) for k, v in s.coll_bytes.items()},
        dict(s.coll_count))
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
                "peak_bytes": float(
                    getattr(ma, "peak_memory_in_bytes",
                            getattr(ma, "temp_size_in_bytes", 0))),
                "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
            }
    except Exception:
        pass
    return roofline_from_parts(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        per_device_flops=flops, per_device_bytes=bytes_accessed,
        coll=coll, model_flops=model_flops, hw=hw, mem=mem, note=note)


def model_flops_for(cfg, cell, n_tokens: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens/step.

    For decode cells D = global_batch (one token each); for train/prefill
    D = global_batch × seq.  Prefill uses the 2·N·D forward-only count.
    """
    n_active = cfg.n_active_params()
    if n_tokens is None:
        if cell.kind == "decode":
            n_tokens = cell.global_batch
        else:
            n_tokens = cell.global_batch * cell.seq_len
    factor = 6.0 if cell.kind == "train" else 2.0
    return factor * n_active * n_tokens
