"""Loop-aware static cost model over the compiled (partitioned) HLO module.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-over-layers programs (a 96-layer model reports ~1 layer of FLOPs).
This walker parses ``compiled.as_text()`` and:

  * builds a symbol table (op name -> result type) per computation,
  * computes per-computation dot/conv FLOPs (exact, from shapes + dnums),
    per-op HBM traffic (operands + result of every *top-level* op — fusion
    bodies contribute zero traffic: only a fused kernel's inputs/outputs
    touch HBM), and collective operand bytes by op kind,
  * resolves the call graph (while/call/fusion/conditional), extracts while
    trip counts from the loop condition's comparison constant, and
  * folds everything up from the entry computation with loop multipliers.

All quantities are per-device (the module is one shard's program).  This is
the "profile" of the dry-run regime: lowered IR + static math, no wall
clocks (PALLAS-SPECIFIC HINTS in the task spec).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# %name = <type> <op>(...)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+)$")
_TYPE_RE = re.compile(
    r"^((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')


def _shape_info(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All (dtype, dims) found in a type string (tuples expand)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",") if d)
            out.append((dt, shape))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, shape in _shape_info(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    symbols: Dict[str, str]            # op name -> result type string


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0                 # dot + conv FLOPs (per device)
    bytes: float = 0.0                 # HBM traffic estimate (per device)
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                name = m.group(1).lstrip("%")
                cur = Computation(name, [], {})
                if line.strip().startswith("ENTRY"):
                    entry = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        tm = _TYPE_RE.match(rhs)
        if not tm:
            # parameters: "%p = bf16[...] parameter(0)" matches; constants of
            # tuple type etc. may not — record type anyway
            sm = _SHAPE_RE.search(rhs)
            cur.symbols[name] = rhs.split(" ", 1)[0] if sm else ""
            continue
        rtype, opcode = tm.group(1), tm.group(2)
        paren = rhs[tm.end() - 1:]
        # operand list: first balanced paren group
        depth, i = 0, 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str = paren[1:i]
        operands = _OPERAND_RE.findall(operand_str)
        cur.symbols[name] = rtype
        cur.ops.append(OpInfo(name, opcode, rtype, operands, rhs))
    return comps, entry


def _dims_from(type_str: str) -> Tuple[str, Tuple[int, ...]]:
    infos = _shape_info(type_str)
    return infos[0] if infos else ("f32", ())


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    """2 × |result| × contracted-size; contracted sizes from the lhs type."""
    res_infos = _shape_info(op.result_type)
    if not res_infos:
        return 0.0
    _, rshape = res_infos[0]
    n_out = 1
    for d in rshape:
        n_out *= d
    lhs = op.operands[0] if op.operands else None
    lhs_type = comp.symbols.get(lhs, "") if lhs else ""
    _, lshape = _dims_from(lhs_type)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.line)
    contracted = 1
    if m and lshape:
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lshape):
                    contracted *= lshape[i]
    return 2.0 * n_out * contracted


def _conv_flops(op: OpInfo, comp: Computation) -> float:
    """2 × |result| × (kernel_spatial × in_ch / groups) — close enough for
    the depthwise/frontend convs in this model zoo."""
    res = _shape_info(op.result_type)
    if not res:
        return 0.0
    _, rshape = res[0]
    n_out = 1
    for d in rshape:
        n_out *= d
    if len(op.operands) < 2:
        return 0.0
    _, kshape = _dims_from(comp.symbols.get(op.operands[1], ""))
    k_elems = 1
    for d in kshape:
        k_elems *= d
    out_ch = rshape[-1] if rshape else 1
    m = re.search(r"feature_group_count=(\d+)", op.line)
    groups = int(m.group(1)) if m else 1
    per_out = k_elems / max(out_ch, 1) if out_ch else k_elems
    del groups  # already folded into kernel shape
    return 2.0 * n_out * per_out


_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota",
}


def _op_traffic(op: OpInfo, comp: Computation) -> float:
    if op.opcode in _NO_TRAFFIC:
        return 0.0
    total = _type_bytes(op.result_type)
    for o in op.operands:
        total += _type_bytes(comp.symbols.get(o, ""))
    return float(total)


def _collective_operand_bytes(op: OpInfo, comp: Computation) -> float:
    total = 0.0
    for o in op.operands:
        total += _type_bytes(comp.symbols.get(o, ""))
    if total == 0.0:
        total = float(_type_bytes(op.result_type))
    return total


def _trip_count(while_line: str, cond: Optional[Computation]) -> int:
    """Prefer the compiler's ``known_trip_count`` backend_config; fall back
    to the max integer constant in the loop condition (jax: ``iter < N``)."""
    m = _TRIP_RE.search(while_line)
    if m:
        return max(int(m.group(1)), 1)
    best = 1
    if cond is not None:
        for op in cond.ops:
            for cm in re.finditer(r"constant\((-?\d+)\)", op.line):
                best = max(best, int(cm.group(1)))
    return max(best, 1)


# ---------------------------------------------------------------------------
# fold-up
# ---------------------------------------------------------------------------


def _refs(op: OpInfo) -> List[Tuple[str, float, bool]]:
    """(computation, extra multiplier, counts_traffic) referenced by an op."""
    out = []
    line = op.line
    if op.opcode == "while":
        cm = re.search(r"condition=(%?[\w\.\-]+)", line)
        bm = re.search(r"body=(%?[\w\.\-]+)", line)
        out.append(("__while__", 0.0, False))  # marker, handled by caller
        if cm and bm:
            out.append((bm.group(1).lstrip("%"), -1.0, True))   # body
            out.append((cm.group(1).lstrip("%"), -1.0, True))   # cond
    elif op.opcode == "fusion":
        m = re.search(r"calls=(%?[\w\.\-]+)", line)
        if m:
            out.append((m.group(1).lstrip("%"), 1.0, False))    # flops only
    elif op.opcode in ("call", "custom-call"):
        m = re.search(r"to_apply=(%?[\w\.\-]+)", line)
        if m:
            out.append((m.group(1).lstrip("%"), 1.0, True))
    elif op.opcode == "conditional":
        for m in re.finditer(r"(%?[\w\.\-]+)_computation", line):
            pass
        m = re.search(r"branch_computations={([^}]*)}", line)
        if m:
            for name in m.group(1).split(","):
                out.append((name.strip().lstrip("%"), 1.0, True))
        else:
            for key in ("true_computation", "false_computation"):
                m2 = re.search(key + r"=(%?[\w\.\-]+)", line)
                if m2:
                    out.append((m2.group(1).lstrip("%"), 1.0, True))
    return out


def summarize(text: str) -> CostSummary:
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: assume the largest computation is the entry
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
    summary = CostSummary()
    if entry is None:
        return summary

    def visit(cname: str, mult: float, traffic: bool, depth: int = 0):
        comp = comps.get(cname)
        if comp is None or depth > 64:
            return
        for op in comp.ops:
            if op.opcode == "dot":
                summary.flops += mult * _dot_flops(op, comp)
            elif op.opcode == "convolution":
                summary.flops += mult * _conv_flops(op, comp)
            if op.opcode.split("-start")[0] in _COLLECTIVES:
                base = op.opcode.split("-start")[0]
                b = mult * _collective_operand_bytes(op, comp)
                summary.coll_bytes[base] = summary.coll_bytes.get(base, 0.0) + b
                summary.coll_count[base] = (summary.coll_count.get(base, 0)
                                            + int(round(mult)))
            if traffic:
                summary.bytes += mult * _op_traffic(op, comp)
            # recurse
            if op.opcode == "while":
                cm = re.search(r"condition=(%?[\w\.\-]+)", op.line)
                bm = re.search(r"body=(%?[\w\.\-]+)", op.line)
                if cm and bm:
                    cond = comps.get(cm.group(1).lstrip("%"))
                    trips = _trip_count(op.line, cond)
                    visit(bm.group(1).lstrip("%"), mult * trips, True,
                          depth + 1)
            elif op.opcode == "fusion":
                m = re.search(r"calls=(%?[\w\.\-]+)", op.line)
                if m:
                    # fusion body: count FLOPs (dots fused in), no traffic
                    visit(m.group(1).lstrip("%"), mult, False, depth + 1)
            elif op.opcode in ("call", "custom-call"):
                m = re.search(r"to_apply=(%?[\w\.\-]+)", op.line)
                if m:
                    visit(m.group(1).lstrip("%"), mult, traffic, depth + 1)
            elif op.opcode == "conditional":
                m = re.search(r"branch_computations={([^}]*)}", op.line)
                names = []
                if m:
                    names = [n.strip().lstrip("%")
                             for n in m.group(1).split(",")]
                else:
                    for key in ("true_computation", "false_computation"):
                        m2 = re.search(key + r"=(%?[\w\.\-]+)", op.line)
                        if m2:
                            names.append(m2.group(1).lstrip("%"))
                for n in names:
                    visit(n, mult, traffic, depth + 1)

    visit(entry, 1.0, True)
    return summary
