"""Cross-pod gradient synchronization over the PGAS conduit layer.

The ``pod`` mesh axis crosses data-center network (~25× slower than ICI);
the only traffic on it is the data-parallel gradient all-reduce, once per
step.  This module makes that hop an explicit, *selectable* transport: the
reduction goes through a :class:`repro.core.conduit.Conduit` (``ring`` by
default — the paper's GASNet extended-API collective carrying real
training traffic — but any registered transport, or ``auto`` for
cost-model selection, works), and int8 compression is a *conduit wrapper*
(:class:`Int8Conduit`), not a transport property:

  * uncompressed — ``conduit.all_reduce``: the bandwidth-optimal ring
    all-reduce built from one-sided ``fshmem_put`` ``ppermute`` hops
    (or whichever transport the conduit names);
  * compressed — :class:`Int8Conduit` quantizes each pod's
    (error-feedback-corrected) gradient to int8 with per-block scales
    (``optim/compress.py``), ships the *int8* payloads and fp32 scales over
    the base conduit's all-gather, and dequantizes-and-averages what
    arrived.  Only ~1/4 of the bytes cross the DCN (:func:`wire_bytes`),
    and the int8 payload is visible as ``s8[`` operands of the lowered
    collective-permutes — asserted by
    ``tests/test_dist.py::TestCrossPodGradSync``.

Error feedback: the quantization residual ``e' = (g + e) − Q(g + e)`` is
returned per leaf; re-injecting it next step keeps Adam convergence
unbiased in practice (Karimireddy et al., 2019).

:func:`bucketed_cross_pod_all_reduce` is the overlapped schedule of the
same contract: the pytree packs into size-targeted whole-leaf buckets
(``dist/bucketing.py``) and each bucket's reduction launches as its
payload is ready — bucket *k*'s collective in flight while bucket *k±1*
packs/(de)quantizes (``pipeline.streamed``, DESIGN §3) — with
:func:`bucket_wire_bytes` accounting the wire per bucket.

Layout contract: each leaf's *local shard along the pod axis* is that pod's
gradient — callers hand this function *per-pod* (not yet pod-reduced)
gradients, pod-sharded on the leading dim by default (``specs`` overrides
the layout).  The caller also owns the error-feedback state across steps:
feed the returned residuals back via ``ef`` on the next call.

Scope note: this transport is not wired inside the GSPMD train step —
producing per-pod gradients there needs partial-manual ``shard_map`` over
``pod`` (manual pod, auto data/model), which the pinned toolchain's SPMD
partitioner rejects (hard ``IsManualSubgroup`` check failure).  Until the
toolchain moves, the ring is exercised standalone and by the dist tests;
see DESIGN §6 and the ROADMAP open item.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import pipeline as pl
from repro.core.conduit import Conduit
from repro.dist import bucketing
from repro.optim.compress import (
    compress_8bit,
    compressed_bytes,
    decompress_8bit,
    ef_init,
)


def bucket_wire_bytes(bucket_elements: Sequence[int], *,
                      compressed: bool = False,
                      block: int = 256) -> Tuple[int, ...]:
    """Per-bucket cross-pod wire bytes (per hop direction).

    Each bucket is one contiguous payload on the wire: fp32 uncompressed,
    or int8 + fp32 per-``block`` scales when compressed.  Padding and
    scale overhead accrue **per bucket** — which is why this is the
    canonical accounting: a whole-pytree element count run through the
    old scalar form understates the compressed wire once sync is bucketed
    (every bucket pads to its own block boundary and ships its own
    scales).  ``bucket_elements`` is what
    :meth:`repro.dist.bucketing.BucketPlan.bucket_elements` returns.
    """
    if not compressed:
        return tuple(4 * int(n) for n in bucket_elements)
    return tuple(compressed_bytes(int(n), block) for n in bucket_elements)


def wire_bytes(n_elements: int, *, compressed: bool = False,
               block: int = 256) -> int:
    """Bytes a tensor of ``n_elements`` puts on the cross-pod wire per hop
    direction — the single-bucket wrapper over
    :func:`bucket_wire_bytes` (kept for callers that account one tensor
    at a time)."""
    return bucket_wire_bytes((n_elements,), compressed=compressed,
                             block=block)[0]


@dataclasses.dataclass(frozen=True)
class Int8Conduit:
    """Conduit wrapper: error-feedback int8 on the wire.

    Wraps any base conduit; ``all_reduce_mean_ef`` quantizes locally,
    rides the base conduit's all-gather with int8 payloads + fp32 scales,
    and dequantizes/averages at the receiver.  Composes with every
    registered transport — compression is orthogonal to the schedule.
    """

    base: Conduit
    block: int = 256

    def all_reduce_mean_ef(self, g, e) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(mean over axis of Q(g+e), new EF residual)."""
        from jax import lax

        n = lax.axis_size(self.base.axis)
        corrected = g.astype(jnp.float32) + e
        q, scale = compress_8bit(corrected, self.block)
        # one gather moves every pod's int8 payload + scales to every pod
        q_all = self.base.all_gather(q[None])          # (n, padded)
        s_all = self.base.all_gather(scale[None])      # (n, n_blocks)
        acc = jnp.zeros(g.shape, jnp.float32)
        for i in range(n):
            acc = acc + decompress_8bit(q_all[i], s_all[i], g.shape,
                                        self.block)
        synced = (acc / n).astype(g.dtype)
        ef_new = corrected - decompress_8bit(q, scale, g.shape, self.block)
        return synced, ef_new


def _leaf_uncompressed(g, e, *, conduit: Conduit, n: int):
    """Exact mean over pods via the conduit's all-reduce.  Any outstanding
    error-feedback residual is flushed into the (lossless) reduction so a
    compressed→uncompressed schedule switch never drops gradient mass."""
    synced = conduit.all_reduce(g.astype(jnp.float32) + e) / n
    return synced.astype(g.dtype), jnp.zeros(g.shape, jnp.float32)


def cross_pod_all_reduce(
    grads,
    mesh,
    *,
    axis: str = "pod",
    compressed: bool = False,
    transport: str = "ring",
    chunk_bytes: Optional[int] = None,
    ef=None,
    block: int = 256,
    specs=None,
) -> Tuple[object, object]:
    """All-reduce-mean ``grads`` across the ``axis`` mesh dimension through
    the selected PGAS conduit.  Returns ``(synced_grads, ef_residuals)``.

    ``transport``: any transport registered for ``all_reduce``/``all_gather``
    (``ring``/``bidir``/``xla``) or ``auto`` for netmodel selection;
    ``compressed``: wrap the conduit in :class:`Int8Conduit` (EF-int8 wire);
    ``ef``: previous error-feedback residuals (zeros when None);
    ``specs``: per-leaf PartitionSpecs of the *input* layout — defaults to
    pod-sharded on each leaf's leading dim."""
    if ef is None:
        ef = ef_init(grads)
    n = mesh.shape[axis]
    if n == 1:
        return grads, ef

    conduit = Conduit(axis=axis, transport=transport, chunk_bytes=chunk_bytes)
    int8 = Int8Conduit(conduit, block=block) if compressed else None

    if specs is None:
        specs = jax.tree.map(
            lambda g: P(axis, *([None] * (max(g.ndim, 1) - 1))), grads)
    ef_specs = specs

    def body(g_tree, e_tree):
        flat_g, treedef = jax.tree.flatten(g_tree)
        flat_e = treedef.flatten_up_to(e_tree)
        if int8 is not None:
            outs = [int8.all_reduce_mean_ef(g, e)
                    for g, e in zip(flat_g, flat_e)]
        else:
            outs = [_leaf_uncompressed(g, e, conduit=conduit, n=n)
                    for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in outs]),
                treedef.unflatten([o[1] for o in outs]))

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(specs, ef_specs),
        out_specs=(specs, ef_specs),
        check_vma=False,
    )
    return fn(grads, ef)


# ---------------------------------------------------------------------------
# Bucketed + streamed sync (the generalized-ART schedule for the DCN hop)
# ---------------------------------------------------------------------------


def bucketed_cross_pod_all_reduce(
    grads,
    mesh,
    *,
    axis: str = "pod",
    bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
    compressed: bool = False,
    transport: str = "ring",
    chunk_bytes: Optional[int] = None,
    ef=None,
    block: int = 256,
    specs=None,
    streamed: bool = True,
) -> Tuple[object, object]:
    """All-reduce-mean ``grads`` across ``axis`` in size-targeted buckets.

    The leaf-by-leaf schedule of :func:`cross_pod_all_reduce` puts one
    message per leaf on the wire — hundreds of small latencies, nothing
    overlapping.  Here the pytree is packed into ``bucket_bytes`` buckets
    (``dist/bucketing.py``: whole leaves, flatten order) and each bucket's
    reduction launches as its payload is ready: with ``streamed=True`` the
    per-bucket schedule rides ``pipeline.streamed``, so bucket *k*'s
    conduit collective is in flight while bucket *k−1*'s local compute —
    int8 dequantize/average when ``compressed``, the mean otherwise — runs
    underneath (and bucket *k+1*'s quantize behind that).
    ``streamed=False`` issues the identical per-bucket calls
    bulk-synchronously — same ops, same order per element, so the two
    schedules are bit-identical (asserted by
    ``tests/test_pipeline.py::TestBucketedSync``).

    Compression quantizes each packed bucket as one tensor (per-``block``
    scales), so the wire carries exactly
    ``bucket_wire_bytes(plan.bucket_elements(), compressed=True)`` — the
    per-bucket accounting this schedule makes canonical.  The EF residual
    keeps the bulk contract: per-leaf fp32, re-injected next step.

    Layout contract and return value match :func:`cross_pod_all_reduce`:
    per-pod gradients in, ``(synced_mean, ef_residuals)`` out.
    """
    if ef is None:
        ef = ef_init(grads)
    n = mesh.shape[axis]
    if n == 1:
        return grads, ef

    conduit = Conduit(axis=axis, transport=transport, chunk_bytes=chunk_bytes)
    if specs is None:
        specs = jax.tree.map(
            lambda g: P(axis, *([None] * (max(g.ndim, 1) - 1))), grads)

    def body(g_tree, e_tree):
        plan = bucketing.bucket_plan(g_tree, target_bytes=bucket_bytes)
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, g_tree, e_tree)
        bufs = bucketing.pack(corrected, plan)

        if compressed:
            def issue(k):
                # quantize bucket k (compute) feeds its gather (wire); the
                # gather of bucket k flies while bucket k−1 dequantizes
                q, scale = compress_8bit(bufs[k], block)
                return (q, scale, conduit.all_gather(q[None]),
                        conduit.all_gather(scale[None]))

            def consume(k, arrived):
                q, scale, q_all, s_all = arrived
                shape = bufs[k].shape
                acc = jnp.zeros(shape, jnp.float32)
                for i in range(n):
                    acc = acc + decompress_8bit(q_all[i], s_all[i], shape,
                                                block)
                ef_buf = bufs[k] - decompress_8bit(q, scale, shape, block)
                return acc / n, ef_buf
        else:
            def issue(k):
                # outstanding EF flushes into the lossless reduction, as in
                # the bulk path
                return conduit.all_reduce(bufs[k])

            def consume(k, arrived):
                return arrived / n, jnp.zeros_like(bufs[k])

        if streamed:
            outs = pl.streamed(plan.n_buckets, issue, consume)
        else:
            outs = [consume(k, issue(k)) for k in range(plan.n_buckets)]

        synced = bucketing.unpack([o[0] for o in outs], plan)
        synced = jax.tree.map(lambda s, g: s.astype(g.dtype), synced, g_tree)
        if compressed:
            ef_new = bucketing.unpack([o[1] for o in outs], plan,
                                      dtype=jnp.float32)
        else:
            ef_new = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), g_tree)
        return synced, ef_new

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(specs, specs),
        out_specs=(specs, specs),
        check_vma=False,
    )
    return fn(grads, ef)
