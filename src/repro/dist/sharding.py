"""Sharding rules: model pytrees → PartitionSpec trees (GSPMD side).

One table of positional rules maps every parameter / cache / batch leaf to a
``PartitionSpec`` over the logical axes in :class:`MeshAxes`:

  * column-parallel projections (``wq``/``w_up``/...) put their output dim on
    ``model`` and their input dim on ``data`` (Megatron TP × ZeRO/FSDP);
  * row-parallel projections (``wo``/``w_down``/...) are the transpose;
  * the embedding is vocab-parallel (``model`` on the vocab dim);
  * norms/scalars replicate.

Rules are *right-aligned* against the leaf shape, so the same table covers a
bare layer and the ``lax.scan``-stacked layer pytree (the leading layer axis
— and the MoE expert axis — pad with ``None``).

Every assignment is guarded by a divisibility check against the mesh: an
axis whose extent does not divide the dim is dropped (replicated) rather
than emitted, so irregular vocab/head counts degrade gracefully instead of
failing to place (the fallback asserted by ``tests/test_dist.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

AxisEntry = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical roles of mesh axes.

    ``data``: DP/FSDP axes — a tuple (e.g. ``("pod", "data")``) spans the
    cross-pod DCN hop; ``model``: tensor/sequence parallelism (ICI);
    ``expert``: expert parallelism — MoE expert weights shard their leading
    E dim over it, and for everything *else* it behaves as one more data
    axis (tokens shard over it, dense params replicate along it), which is
    what lets ``models/moe_ep.py`` route distinct tokens per expert shard.
    """

    data: AxisEntry = "data"
    model: AxisEntry = "model"
    expert: AxisEntry = "expert"

    def names(self, entry: AxisEntry) -> Tuple[str, ...]:
        """An entry as a flat tuple of mesh-axis names (None → empty)."""
        if entry is None:
            return ()
        return (entry,) if isinstance(entry, str) else tuple(entry)


def dp_axes(mesh) -> AxisEntry:
    """The full data-parallel axis set of ``mesh``.

    Includes ``pod`` (DCN) and ``expert`` when present: the expert axis
    carries distinct tokens like any data axis — only MoE expert weights
    treat it specially (see :func:`param_pspecs`)."""
    names = tuple(a for a in ("pod", "data", "expert")
                  if a in mesh.axis_names)
    if not names:
        return None
    return names[0] if len(names) == 1 else names


def fit_axis(mesh, entry: AxisEntry, dim: int) -> AxisEntry:
    """``entry`` if every named axis exists and their product divides
    ``dim``; otherwise ``None`` (replicate — the divisibility fallback).
    The single owner of the drop-don't-fail placement rule; every spec
    builder (here and in ``dist/steps.py``) goes through it."""
    if entry is None:
        return None
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    size = 1
    for name in names:
        if name not in mesh.axis_names:
            return None
        size *= mesh.shape[name]
    return entry if (size > 0 and dim % size == 0) else None


# -- parameter rules ---------------------------------------------------------

# (in_dim, out_dim) projections: output column-sharded on model, input on
# data.  Covers GQA/MLA attention, dense/MoE MLPs and the Mamba projections.
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "w_up", "w_gate", "in_proj",
    "w_dq", "w_dkv", "w_uq", "w_uk", "w_uv",
})
# (in_dim, out_dim) with the *input* dim model-sharded (row-parallel).
_ROW_PARALLEL = frozenset({"wo", "w_down", "out_proj"})
# Small per-layer vectors: replicate.
_REPLICATED = frozenset({
    "scale", "bias", "dt_bias", "a_log", "d_skip", "conv_b", "step",
})
# MoE routed-expert tensors (leading E dim under params["moe"]): the E dim
# shards over the ``expert`` axis when the mesh has one.
_EXPERT_PARALLEL = frozenset({"w_up", "w_gate", "w_down"})


def _param_rule(name: str, shape: Tuple[int, ...], mesh,
                axes: MeshAxes, parent: str = "") -> P:
    d, m = axes.data, axes.model
    if name in _REPLICATED or len(shape) == 0:
        return P()
    if name == "embed":
        base: Tuple[AxisEntry, ...] = (m, d)      # vocab-parallel
    elif name == "lm_head":
        base = (d, m)
    elif name in _COL_PARALLEL:
        base = (d, m)
    elif name in _ROW_PARALLEL:
        base = (m, d)
    elif name == "router":
        base = (d, None)
    elif name == "conv_w":
        base = (None, m)
    elif name in ("dec_pos", "frontend_proj"):
        base = (None, d)
    else:
        return P()
    # routed-expert tensors carry a leading E dim ahead of the (in, out)
    # pair; ``parent == "moe"`` distinguishes them from the same-named
    # dense projections (incl. the shared expert under "shared")
    if parent == "moe" and name in _EXPERT_PARALLEL and len(shape) >= 3:
        base = (axes.expert,) + base
    k = min(len(base), len(shape))
    base = base[len(base) - k:]
    tail = shape[len(shape) - k:]
    entries = [None] * (len(shape) - k)
    entries += [fit_axis(mesh, e, dim) for e, dim in zip(base, tail)]
    return P(*entries)


def _leaf_name(path, idx: int = -1) -> str:
    if len(path) < -idx:
        return ""
    last = path[idx]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def param_pspecs(cfg: ModelConfig, mesh, params,
                 axes: Optional[MeshAxes] = None):
    """PartitionSpec tree for a parameter pytree (arrays or shape structs).

    Parameters stay *within-pod*: the default axes never shard over ``pod``
    — only the gradient all-reduce crosses the DCN (DESIGN §6).  MoE
    routed-expert weights additionally shard their leading E dim over the
    ``expert`` axis when the mesh has one (expert parallelism)."""
    del cfg  # rules are shape/name driven; cfg kept for API stability
    axes = axes or MeshAxes()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_param_rule(_leaf_name(path), tuple(leaf.shape), mesh, axes,
                         parent=_leaf_name(path, -2))
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(cfg: ModelConfig, mesh, opt, pspecs,
               axes: Optional[MeshAxes] = None) -> Dict[str, Any]:
    """Optimizer-state specs mirror the parameter specs leaf-for-leaf
    (ZeRO: moments and the fp32 master shard exactly like their param)."""
    del cfg, mesh, axes
    return {key: (P() if key == "step" else pspecs) for key in opt}


# -- cache / batch rules -----------------------------------------------------

_KV_LIKE = frozenset({
    "k", "v", "attn_k", "attn_v", "cross_k", "cross_v", "ckv", "krope",
})


def cache_pspecs(cfg: ModelConfig, mesh, cache,
                 axes: Optional[MeshAxes] = None) -> Dict[str, P]:
    """Decode-cache specs: batch on ``data``; the KV ring-buffer sequence dim
    on ``model`` (sequence-sharded cache — robust to n_kv < tp); SSM heads on
    ``model``; position bookkeeping replicated."""
    del cfg
    axes = axes or MeshAxes()
    d, m = axes.data, axes.model
    specs: Dict[str, P] = {}
    for key, leaf in cache.items():
        shape = tuple(leaf.shape)
        if key in ("pos", "slot_pos", "block_ids") or len(shape) < 2:
            specs[key] = P()                    # bookkeeping: replicate
            continue
        entries: list = [None] * len(shape)
        entries[1] = fit_axis(mesh, d, shape[1])          # (stack, batch, ...)
        if key in _KV_LIKE and len(shape) >= 3:
            entries[-2] = fit_axis(mesh, m, shape[-2])    # sequence/buffer dim
        elif key == "ssm_state" and len(shape) >= 3:
            entries[2] = fit_axis(mesh, m, shape[2])      # SSM heads
        elif key == "conv_state":
            entries[-1] = fit_axis(mesh, m, shape[-1])    # conv channels
        specs[key] = P(*entries)
    return specs


def batch_pspecs(mesh, batch, axes: Optional[MeshAxes] = None):
    """Batch specs: leading (example) dim over the *full* DP axis set —
    including ``pod`` when present; everything else replicated."""
    dp = axes.data if axes is not None else dp_axes(mesh)

    def rule(leaf) -> P:
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        return P(fit_axis(mesh, dp, shape[0]), *([None] * (len(shape) - 1)))

    return jax.tree.map(rule, batch)


def to_shardings(mesh, specs):
    """PartitionSpec tree → NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
