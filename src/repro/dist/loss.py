"""Sequence-chunked cross-entropy: the training loss without full logits.

``models/model.loss_fn`` materializes (B, S, V) float32 logits — fine for
tests, catastrophic for large-vocab archs at train shapes (nemotron's 256k
vocab at 4k×256 tokens would be ~1 TB of logits).  ``chunked_ce_loss``
computes the identical quantity by streaming the LM head over sequence
chunks: per chunk it forms (B, C, V) logits, reduces them to three partial
sums (masked NLL, masked squared-logsumexp for z-loss, token count), and
drops them.  Peak logit memory is V·C instead of V·S per row.

Numerics match ``loss_fn`` to float32 reassociation error (asserted at
rtol 1e-5 by ``tests/test_dist.py::TestChunkedCE``): the per-position
logsumexp is independent of chunking, and the final normalization uses the
same global masked-token denominator.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward_hidden
from repro.models.shardctx import constrain


def chunked_ce_loss(
    cfg: ModelConfig,
    params,
    batch: Dict[str, jnp.ndarray],
    *,
    seq_chunk: int,
    z_loss: float = 1e-4,
    moe_aux_weight: float = 1e-2,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens (B, S), labels (B, S) with -1 = masked, plus optional
    frontend_embeds.  Returns (total_loss, metrics) exactly like
    ``models.model.loss_fn``."""
    hidden, aux = forward_hidden(cfg, params, batch["tokens"],
                                 batch.get("frontend_embeds"))
    labels = batch["labels"]
    if hidden.shape[1] != labels.shape[1]:   # vlm: crop frontend positions
        hidden = hidden[:, hidden.shape[1] - labels.shape[1]:]

    cd = jnp.dtype(cfg.compute_dtype)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cd)

    s = labels.shape[1]
    chunk = max(int(seq_chunk), 1)
    nll_sum = jnp.zeros((), jnp.float32)
    z_sum = jnp.zeros((), jnp.float32)
    tokens = jnp.zeros((), jnp.float32)
    for start in range(0, s, chunk):
        h_c = constrain(hidden[:, start:start + chunk], "logit_hidden")
        lab = labels[:, start:start + chunk]
        logits = jnp.einsum("bsd,dv->bsv", h_c.astype(cd),
                            head).astype(jnp.float32)
        mask = (lab >= 0).astype(jnp.float32)
        safe = jnp.maximum(lab, 0)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll_sum += ((lse - gold) * mask).sum()
        z_sum += ((lse * mask) ** 2).sum()
        tokens += mask.sum()

    denom = jnp.maximum(tokens, 1.0)
    ce = nll_sum / denom
    zl = z_loss * z_sum / denom
    total = ce + zl + moe_aux_weight * aux
    return total, {"ce": ce, "z_loss": zl, "moe_aux": aux, "tokens": tokens}
