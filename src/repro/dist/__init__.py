"""Distribution layer: sharding rules, chunked loss, step builders, and the
PGAS-backed cross-pod gradient transport.

This is the layer between the mesh-agnostic model zoo (``repro.models``)
and the runtimes (``repro.runtime``): it decides where every tensor lives
(``sharding``), how the loss streams over the vocabulary (``loss``), how a
train/prefill/serve step is jitted onto a mesh (``steps``), and which
transport the once-per-step cross-pod gradient all-reduce takes
(``grad_sync`` — the software analogue of the paper's 2-node case study).
"""

from repro.dist import bucketing, grad_sync, loss, sharding, steps
from repro.dist.bucketing import (BucketPlan, bucket_plan,
                                  span_scaled_target)
from repro.dist.grad_sync import (
    Int8Conduit,
    bucket_wire_bytes,
    bucketed_cross_pod_all_reduce,
    cross_pod_all_reduce,
    wire_bytes,
)
from repro.dist.loss import chunked_ce_loss
from repro.dist.sharding import (
    MeshAxes,
    batch_pspecs,
    cache_pspecs,
    opt_pspecs,
    param_pspecs,
    to_shardings,
)
from repro.dist.steps import (
    StepBundle,
    StepConfig,
    TransportPolicy,
    build_block_write_step,
    build_init,
    build_prefill_chunk_step,
    build_prefill_step,
    build_serve_step,
    build_slot_write_step,
    build_train_step,
    refit_step_config,
)

__all__ = [
    "bucketing", "grad_sync", "loss", "sharding", "steps",
    "BucketPlan", "bucket_plan", "span_scaled_target",
    "Int8Conduit", "bucket_wire_bytes", "bucketed_cross_pod_all_reduce",
    "cross_pod_all_reduce", "wire_bytes", "chunked_ce_loss",
    "MeshAxes", "batch_pspecs", "cache_pspecs", "opt_pspecs",
    "param_pspecs", "to_shardings",
    "StepBundle", "StepConfig", "TransportPolicy",
    "build_block_write_step", "build_init",
    "build_prefill_chunk_step", "build_prefill_step", "build_serve_step",
    "build_slot_write_step", "build_train_step", "refit_step_config",
]
