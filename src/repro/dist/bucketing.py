"""Size-targeted gradient buckets for streamed reduction and accumulation.

The bulk gradient sync reduces the pytree leaf-by-leaf: thousands of small
messages, each paying full per-message latency, none overlapping anything.
The bucketing line of work (Rajbhandari et al., SC'20; torch DDP's
``bucket_cap_mb``) flattens leaves into a few size-targeted buffers so the
wire sees large messages *and* each bucket's reduction can launch as soon
as the bucket is ready — the payload-partitioning half of the generalized
ART scheduler (``core/pipeline.py``); ``dist/grad_sync.py`` supplies the
overlap half.

Two invariants keep bucketing numerics-neutral:

* **whole leaves only** — a leaf is never split across buckets, so int8
  block quantization (``optim/compress.py``) and per-bucket wire
  accounting (``grad_sync.bucket_wire_bytes``) see the same contiguous
  payloads no matter how leaves are grouped, and unpacking is a static
  slice + reshape;
* **flatten order** — buckets are contiguous runs of the pytree's leaf
  order, so pack → elementwise op → unpack touches every element exactly
  once, in place (bucketed microbatch accumulation in ``dist/steps.py`` is
  bit-identical to the pytree accumulation it replaces).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

#: default bucket size target — large enough to saturate a DCN link,
#: small enough that several buckets exist to pipeline (torch DDP's
#: bucket_cap_mb=25 is the same order of magnitude)
DEFAULT_BUCKET_BYTES = 4 << 20


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """A static partition of a pytree's leaves into size-targeted buckets.

    Hashable and shape-only (no arrays), so it can be closed over by
    jitted code; build once per (tree structure, target) with
    :func:`bucket_plan`.
    """

    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[str, ...]
    buckets: Tuple[Tuple[int, ...], ...]   # leaf indices per bucket

    @property
    def n_buckets(self) -> int:
        """Number of buckets in the plan."""
        return len(self.buckets)

    def leaf_elements(self, i: int) -> int:
        """Element count of leaf ``i`` (flatten order)."""
        return math.prod(self.leaf_shapes[i])

    def bucket_elements(self) -> Tuple[int, ...]:
        """Per-bucket element counts — the sizes ``pack`` buffers will have
        (and what ``grad_sync.bucket_wire_bytes`` accounts)."""
        return tuple(sum(self.leaf_elements(i) for i in b)
                     for b in self.buckets)


def bucket_plan(tree, *, target_bytes: int = DEFAULT_BUCKET_BYTES,
                itemsize: int = 4) -> BucketPlan:
    """Greedy-fill whole leaves (flatten order) into ≤ ``target_bytes``
    buckets.

    A leaf larger than the target gets a bucket of its own — leaves are
    never split (see module invariants).  ``tree`` may hold arrays or
    ``ShapeDtypeStruct``s; only shapes/dtypes are read.  ``itemsize`` is
    the on-the-wire element size the target is measured in (4: the fp32
    accumulation/reduction dtype, regardless of each leaf's at-rest dtype).
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        return BucketPlan(treedef, (), (), ())
    buckets: List[Tuple[int, ...]] = []
    cur: List[int] = []
    cur_bytes = 0
    for i, leaf in enumerate(leaves):
        nbytes = math.prod(leaf.shape) * itemsize
        if cur and cur_bytes + nbytes > target_bytes:
            buckets.append(tuple(cur))
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nbytes
    buckets.append(tuple(cur))
    return BucketPlan(
        treedef,
        tuple(tuple(leaf.shape) for leaf in leaves),
        tuple(str(jnp.dtype(leaf.dtype)) for leaf in leaves),
        tuple(buckets),
    )


def span_scaled_target(target_bytes: int, old_span: int,
                       new_span: int) -> int:
    """Bucket size target re-fitted to a changed gradient-sync span.

    A ring all-reduce of a ``target_bytes`` bucket over an ``n``-rank span
    puts ``target/n`` bytes on each hop — the quantity the target was
    tuned for (the ~2 MiB QSFP / ~16 MiB ICI sweet spots in
    ``BENCH_overlap.json`` are *per-hop* numbers).  When elastic recovery
    shrinks the data axis, keeping the per-hop message constant means
    scaling the bucket target by ``new_span / old_span`` — this is the
    re-fit :meth:`repro.runtime.elastic.ElasticRuntime.on_failure` applies
    before :func:`bucket_plan` runs against the survivors.
    """
    if old_span < 1 or new_span < 1:
        raise ValueError(f"spans must be >= 1 ({old_span} -> {new_span})")
    return max(1, int(target_bytes) * int(new_span) // int(old_span))


def pack(tree, plan: BucketPlan, dtype=jnp.float32) -> List[jnp.ndarray]:
    """Flatten ``tree`` into the plan's buckets: one 1-D ``dtype`` buffer
    per bucket, leaves raveled and concatenated in flatten order."""
    leaves = plan.treedef.flatten_up_to(tree)
    return [
        jnp.concatenate(
            [leaves[i].astype(dtype).reshape(-1) for i in bucket])
        for bucket in plan.buckets
    ]


def unpack(buffers: Sequence[jnp.ndarray], plan: BucketPlan, dtype=None):
    """Invert :func:`pack`: slice each bucket buffer back into its leaves.

    ``dtype`` casts every leaf (e.g. fp32 gradients); ``None`` restores
    each leaf's recorded at-rest dtype.
    """
    out: List[Any] = [None] * len(plan.leaf_shapes)
    for buf, bucket in zip(buffers, plan.buckets):
        off = 0
        for i in bucket:
            n = plan.leaf_elements(i)
            leaf = buf[off:off + n].reshape(plan.leaf_shapes[i])
            out[i] = leaf.astype(dtype or plan.leaf_dtypes[i])
            off += n
    return plan.treedef.unflatten(out)


__all__ = ["DEFAULT_BUCKET_BYTES", "BucketPlan", "bucket_plan",
           "span_scaled_target", "pack", "unpack"]
