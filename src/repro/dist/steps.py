"""Step builders: jit-compiled, mesh-sharded train / prefill / serve steps.

This is the layer that turns the mesh-agnostic model zoo into distributed
programs.  Each ``build_*`` returns a :class:`StepBundle`: a jitted callable
with in/out shardings bound, the matching PartitionSpec trees (so runtimes
can place state without re-deriving rules), and shape templates for
checkpoint restore and dry-run lowering.

Responsibilities:

  * sharding — parameter/optimizer/cache/batch placement from
    ``dist/sharding.py``; activation constraints are injected into the
    model's ``constrain(x, tag)`` call sites via ``models/shardctx.py``
    (sequence-parallel residual when the length divides TP);
  * loss — the sequence-chunked CE from ``dist/loss.py`` (full logits never
    materialize at train shapes);
  * microbatching — ``lax.scan`` gradient accumulation in fp32; with equal
    per-microbatch token counts the update is exactly the full-batch one
    (asserted by ``tests/test_dist.py::test_microbatch_equivalence``);
  * transport selection — :class:`TransportPolicy` names a conduit
    transport per traffic class (TP collectives of dense blocks, MoE
    dispatch, cross-pod gradients).  A non-``xla`` ``tp`` transport swaps
    every TP collective of dense blocks for the conduit-scheduled PGAS
    rings of ``models/artblock.py`` (the paper's ART as a training
    feature); the legacy boolean ``StepConfig.art_tp`` still works through
    a deprecation shim.  A non-``xla`` ``moe`` transport swaps the dense
    GSPMD MoE layer for the expert-parallel bucketed all_to_all dispatch
    of ``models/moe_ep.py`` whenever the mesh has an ``expert`` axis
    (falls back to dense otherwise — same numerics).  The cross-pod
    gradient hop has its own PGAS conduit in ``dist/grad_sync.py``
    (operating on per-pod gradients, pod-sharded layout); wiring it
    *inside* this GSPMD step would require partial-manual shard_map over
    ``pod``, which the pinned jax's partitioner rejects — see DESIGN §6
    and the ROADMAP open item.

See ``docs/api.md`` for the public surface and ``docs/transports.md`` for
the op × transport support matrix these policies select from.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.conduit import Conduit, transports as conduit_transports
from repro.dist import bucketing
from repro.dist.loss import chunked_ce_loss
from repro.dist.sharding import (
    MeshAxes,
    batch_pspecs,
    cache_pspecs,
    dp_axes,
    fit_axis,
    opt_pspecs,
    param_pspecs,
    to_shardings,
)
from repro.models import artblock
from repro.models import layers as L
from repro.models import moe_ep
from repro.models.decode import (
    decode_step,
    init_cache,
    init_paged_cache,
    paged_slot_blocks,
)
from repro.models.model import init_params
from repro.models.prefill import (
    chunk_support,
    init_prefill_scratch,
    prefill,
    prefill_chunk,
    prefill_chunked,
    supports_chunked_prefill,
)
from repro.models.shardctx import activation_sharding
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    warmup_cosine,
)


@dataclasses.dataclass(frozen=True)
class TransportPolicy:
    """Conduit transport per traffic class (DESIGN §6, docs/transports.md).

    Each field names a transport registered in ``repro.core.conduit``
    (``xla`` | ``ring`` | ``bidir`` | ``auto``).  ``xla`` means "leave the
    collective to the GSPMD partitioner" — no manual region is built.

    ``tp``         — TP collectives of dense blocks (QKV/O, up/down rings):
                     any ring family routes them through the ART schedules
                     of ``models/artblock.py`` over a ``Conduit("model")``;
                     ``fused`` pins the in-kernel Pallas collective
                     matmuls (``kernels/cc_matmul``) at those edges;
    ``moe``        — MoE expert dispatch: any non-``xla`` value routes
                     token buckets through the conduit ``all_to_all`` on
                     the ``expert`` mesh axis (``models/moe_ep.py``);
                     meshes without an ``expert`` axis keep the dense
                     GSPMD capacity einsums regardless of this field;
    ``cross_pod``  — the DCN gradient hop (``dist/grad_sync.py``);
    ``compress_cross_pod`` — wrap the cross-pod conduit in EF-int8
                     (``grad_sync.Int8Conduit``);
    ``chunk_bytes`` — ART chunk size handed to every conduit (None: let
                     ``auto`` pick / transport default);
    ``moe_stream_chunks`` — stream the EP dispatch: split each MoE
                     exchange into this many ART chunks so expert compute
                     on bucket *k−1* overlaps bucket *k*'s ``all_to_all``
                     (``models/moe_ep.py``; bit-identical to the bulk
                     exchange; None/1 keeps bulk).
    """

    tp: str = "xla"
    moe: str = "xla"
    cross_pod: str = "ring"
    compress_cross_pod: bool = False
    chunk_bytes: Optional[int] = None
    moe_stream_chunks: Optional[int] = None

    def __post_init__(self):
        # each traffic class validates against the registry of the op it
        # actually rides (tp gathers/scatters, moe dispatches,
        # cross_pod reduces)
        for cls, op in (("tp", "all_gather"), ("moe", "all_to_all"),
                        ("cross_pod", "all_reduce")):
            name = getattr(self, cls)
            valid = ("auto",) + conduit_transports(op)
            if name not in valid:
                raise ValueError(
                    f"TransportPolicy.{cls}={name!r} not in {valid}")

    def tp_conduit(self, axis: str = "model") -> Conduit:
        """The conduit handle the ART-TP schedules run over."""
        return Conduit(axis=axis, transport=self.tp,
                       chunk_bytes=self.chunk_bytes)


@dataclasses.dataclass(frozen=True)
class StepConfig:
    """Per-run knobs of the distributed step (model config stays pure)."""

    microbatches: int = 1
    seq_chunk: int = 512             # CE streaming chunk (dist/loss.py)
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # "bfloat16" for >=100B archs
    master_fp32: bool = True
    sequence_parallel: bool = True   # shard S of the residual over TP
    art_tp: bool = False             # DEPRECATED: use transport=TransportPolicy
    transport: Optional[TransportPolicy] = None
    # microbatch grads accumulate into size-targeted flat buckets
    # (dist/bucketing.py) instead of the leaf pytree: each bucket's add for
    # microbatch k is independent of microbatch k+1's backward, and the
    # bucket layout is what a bucketed conduit sync ships.  None: pytree
    # accumulation (bit-identical either way — asserted in tests).
    grad_bucket_bytes: Optional[int] = None
    z_loss: float = 1e-4
    moe_aux_weight: float = 1e-2

    def resolved_transport(self) -> TransportPolicy:
        """The effective policy, honoring the deprecated ``art_tp`` flag.

        ``art_tp=True`` historically meant "bidirectional PGAS rings for
        every TP collective of dense blocks" — it maps to
        ``TransportPolicy(tp="bidir")``."""
        if self.transport is not None:
            return self.transport
        if self.art_tp:
            warnings.warn(
                "StepConfig.art_tp is deprecated; use "
                "StepConfig(transport=TransportPolicy(tp='bidir'))",
                DeprecationWarning, stacklevel=2)
            return TransportPolicy(tp="bidir")
        return TransportPolicy()


def refit_step_config(scfg: StepConfig, old_data: int,
                      new_data: int) -> StepConfig:
    """Re-fit a :class:`StepConfig` after the data axis changed size.

    Elastic membership changes (``runtime/elastic.py``) keep two
    invariants whether the data axis shrank (rank loss) or grew
    (scale-out join):

    * **global batch constant** — ``microbatches`` scales by
      ``old_data // new_data`` on a shrink (each survivor accumulates the
      shards the dead rank used to hold) and *divides* by
      ``new_data // old_data`` on a growth (the joiner takes shards
      back); either direction must divide cleanly, which
      :func:`repro.runtime.elastic.viable_mesh_shapes` guarantees for
      shrinks and the join admission checks for growths;
    * **per-hop ring message constant** — ``grad_bucket_bytes`` (when
      set) scales by ``new_data / old_data`` via
      :func:`repro.dist.bucketing.span_scaled_target`, since a ring
      all-reduce puts ``target/span`` bytes on each hop.
    """
    if old_data < 1 or new_data < 1:
        raise ValueError(f"data spans must be >= 1 ({old_data} -> {new_data})")
    if old_data % new_data == 0:
        micro = scfg.microbatches * (old_data // new_data)
    elif new_data % old_data == 0:
        factor = new_data // old_data
        if scfg.microbatches % factor != 0:
            raise RuntimeError(
                f"cannot hold global batch: {scfg.microbatches} microbatches "
                f"do not split over growth {old_data} -> {new_data}")
        micro = scfg.microbatches // factor
    else:
        raise RuntimeError(
            f"cannot hold global batch: data axis {old_data} -> {new_data} "
            f"is not a clean shrink or growth")
    changes: Dict[str, Any] = {"microbatches": micro}
    if scfg.grad_bucket_bytes is not None:
        changes["grad_bucket_bytes"] = bucketing.span_scaled_target(
            scfg.grad_bucket_bytes, old_data, new_data)
    return dataclasses.replace(scfg, **changes)


@dataclasses.dataclass
class StepBundle:
    """A built step: jitted fn + the specs/shapes runtimes need around it."""

    fn: Any                          # jitted callable (has .lower)
    in_specs: Tuple[Any, ...]        # PartitionSpec tree per positional arg
    out_specs: Any
    aux: Dict[str, Any]              # params_shape / opt_shape / cache_shape


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------


def _adamw_config(scfg: StepConfig) -> AdamWConfig:
    return AdamWConfig(lr=scfg.peak_lr, weight_decay=scfg.weight_decay,
                       moment_dtype=scfg.moment_dtype,
                       master_fp32=scfg.master_fp32)


def _state_shapes(cfg: ModelConfig, scfg: StepConfig):
    params_shape = jax.eval_shape(functools.partial(init_params, cfg),
                                  jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(
        functools.partial(adamw_init, cfg=_adamw_config(scfg)), params_shape)
    return params_shape, opt_shape


def _tp_extent(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def _constraint_fn(cfg: ModelConfig, mesh, scfg: StepConfig) -> Callable:
    """The ``constrain(x, tag)`` implementation installed for a trace."""
    dp = dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    tp_n = _tp_extent(mesh)

    def constrain(x, tag: str):
        if getattr(x, "ndim", 0) != 3:
            return x
        if tag in ("residual", "block_input"):
            sp = (scfg.sequence_parallel and tp is not None
                  and x.shape[1] % tp_n == 0)
            spec = P(dp, tp if sp else None, None)
        elif tag == "logit_hidden":
            spec = P(dp, None, None)
        else:
            return x
        return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def _scalar_sharding(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# ART-TP block runner (the paper's transport inside the train step)
# ---------------------------------------------------------------------------


def _art_runner(cfg: ModelConfig, mesh,
                policy: TransportPolicy) -> Optional[Callable]:
    """Dense-block runner with every TP collective a PGAS conduit schedule.

    Norms and the (small) K/V projections stay GSPMD; the two manual regions
    differentiate only tp-sharded tensors (see models/artblock.py notes).
    Returns None when ``policy.tp`` leaves TP to GSPMD (``xla``) or the
    arch/mesh cannot take the manual schedule — the step then falls back to
    GSPMD collectives, same numerics.
    """
    tp_n = _tp_extent(mesh)
    if policy.tp == "xla" or tp_n <= 1 \
            or not artblock.supports_art_tp(cfg, tp_n):
        return None
    conduit = policy.tp_conduit("model")
    dp = dp_axes(mesh)
    act3 = P(dp, "model", None)
    cd = jnp.dtype(cfg.compute_dtype)

    def runner(cfg_, lp, x, positions):
        attn_p, mlp_p = lp["attn"], lp["mlp"]
        a_in = L.apply_norm(cfg_, lp["ln1"], x)
        k_full = jnp.einsum("bsd,dh->bsh", a_in.astype(cd),
                            attn_p["wk"].astype(cd))
        v_full = jnp.einsum("bsd,dh->bsh", a_in.astype(cd),
                            attn_p["wv"].astype(cd))

        attn_fn = jax.shard_map(
            functools.partial(artblock.art_attention_part, cfg_,
                              conduit=conduit),
            mesh=mesh,
            in_specs=(act3, act3, act3, act3,
                      P(None, "model"), P("model", None), P(None)),
            out_specs=act3, check_vma=False)
        h = attn_fn(x, a_in, k_full, v_full, attn_p["wq"], attn_p["wo"],
                    positions)

        m_in = L.apply_norm(cfg_, lp["ln2"], h)
        w_gate = mlp_p.get("w_gate")
        if w_gate is not None:
            def gated(h_, m_, wu, wg, wd):
                return artblock.art_mlp_part(cfg_, h_, m_, wu, wg, wd,
                                             conduit=conduit)
            mlp_fn = jax.shard_map(
                gated, mesh=mesh,
                in_specs=(act3, act3, P(None, "model"), P(None, "model"),
                          P("model", None)),
                out_specs=act3, check_vma=False)
            return mlp_fn(h, m_in, mlp_p["w_up"], w_gate, mlp_p["w_down"])

        def ungated(h_, m_, wu, wd):
            return artblock.art_mlp_part(cfg_, h_, m_, wu, None, wd,
                                         conduit=conduit)
        mlp_fn = jax.shard_map(
            ungated, mesh=mesh,
            in_specs=(act3, act3, P(None, "model"), P("model", None)),
            out_specs=act3, check_vma=False)
        return mlp_fn(h, m_in, mlp_p["w_up"], mlp_p["w_down"])

    return runner


# ---------------------------------------------------------------------------
# expert-parallel MoE runner (conduit all_to_all dispatch)
# ---------------------------------------------------------------------------


def _moe_runner(cfg: ModelConfig, mesh,
                policy: TransportPolicy) -> Optional[Callable]:
    """MoE-layer runner with expert dispatch on the conduit ``all_to_all``.

    ``policy.moe="xla"`` (or a mesh without a usable ``expert`` axis)
    returns None — the step keeps the dense GSPMD capacity einsums, same
    numerics.  Otherwise tokens ride the bucketed exchange of
    ``models/moe_ep.py`` over ``Conduit("expert", policy.moe)``.
    """
    if policy.moe == "xla" or cfg.family != "moe":
        return None
    return moe_ep.build_moe_ep_runner(
        cfg, mesh, transport=policy.moe, chunk_bytes=policy.chunk_bytes,
        stream_chunks=policy.moe_stream_chunks)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def build_init(cfg: ModelConfig, mesh, scfg: StepConfig):
    """Returns ``(init_fn, (param_pspecs, opt_pspecs))``; ``init_fn(key)``
    materializes sharded (params, opt_state) directly on the mesh."""
    params_shape, opt_shape = _state_shapes(cfg, scfg)
    pspecs = param_pspecs(cfg, mesh, params_shape)
    ospecs = opt_pspecs(cfg, mesh, opt_shape, pspecs)
    acfg = _adamw_config(scfg)

    @functools.partial(
        jax.jit,
        out_shardings=(to_shardings(mesh, pspecs), to_shardings(mesh, ospecs)))
    def init_fn(key):
        params = init_params(cfg, key)
        return params, adamw_init(params, acfg)

    return init_fn, (pspecs, ospecs)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, mesh, scfg: StepConfig,
                     bshape) -> StepBundle:
    """``fn(params, opt, batch, step) -> (params, opt, metrics)``."""
    params_shape, opt_shape = _state_shapes(cfg, scfg)
    pspecs = param_pspecs(cfg, mesh, params_shape)
    ospecs = opt_pspecs(cfg, mesh, opt_shape, pspecs)
    bspecs = batch_pspecs(mesh, bshape)
    acfg = _adamw_config(scfg)
    constrain = _constraint_fn(cfg, mesh, scfg)
    policy = scfg.resolved_transport()
    runner = _art_runner(cfg, mesh, policy)
    moe_runner = _moe_runner(cfg, mesh, policy)
    n_micro = max(int(scfg.microbatches), 1)

    def loss_fn(params, microbatch):
        with activation_sharding(constrain, tp_block=runner,
                                 moe_ffn=moe_runner):
            return chunked_ce_loss(
                cfg, params, microbatch, seq_chunk=scfg.seq_chunk,
                z_loss=scfg.z_loss, moe_aux_weight=scfg.moe_aux_weight)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step_fn(params, opt, batch, step):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)

            if scfg.grad_bucket_bytes:
                # bucketed accumulation: grads land in size-targeted flat
                # buffers; each bucket's add for microbatch k is
                # independent of microbatch k+1's backward, so the
                # scheduler can drain buckets under the next backward —
                # and the layout is the one a bucketed sync would ship.
                # Per element the fp32 adds are the pytree accumulation's,
                # so the update is bit-identical.
                plan = bucketing.bucket_plan(
                    jax.tree.map(
                        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                        params),
                    target_bytes=scfg.grad_bucket_bytes)

                def body(acc, mb):
                    (l, met), g = grad_fn(params, mb)
                    packed = bucketing.pack(g, plan)
                    acc = tuple(a + p for a, p in zip(acc, packed))
                    return acc, (l, met)

                zeros = tuple(jnp.zeros((m,), jnp.float32)
                              for m in plan.bucket_elements())
                bufs, (losses, mets) = lax.scan(body, zeros, micro)
                grads = bucketing.unpack(
                    [b / n_micro for b in bufs], plan)
            else:
                def body(g_acc, mb):
                    (l, met), g = grad_fn(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), g_acc, g)
                    return g_acc, (l, met)

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                g_sum, (losses, mets) = lax.scan(body, zeros, micro)
                grads = jax.tree.map(lambda a: a / n_micro, g_sum)
            loss = losses.mean()
            metrics = {k: (v.sum() if k == "tokens" else v.mean())
                       for k, v in mets.items()}

        grads, grad_norm = clip_by_global_norm(grads, scfg.clip_norm)
        lr = warmup_cosine(step, peak_lr=scfg.peak_lr,
                           warmup_steps=scfg.warmup_steps,
                           total_steps=scfg.total_steps)
        new_params, new_opt = adamw_update(grads, opt, params, acfg, lr)
        metrics = dict(metrics, loss=loss, grad_norm=grad_norm, lr=lr)
        return new_params, new_opt, metrics

    psh = to_shardings(mesh, pspecs)
    osh = to_shardings(mesh, ospecs)
    bsh = to_shardings(mesh, bspecs)
    scalar = _scalar_sharding(mesh)
    fn = jax.jit(step_fn, in_shardings=(psh, osh, bsh, scalar),
                 out_shardings=(psh, osh, scalar))
    return StepBundle(
        fn=fn,
        in_specs=(pspecs, ospecs, bspecs, P()),
        out_specs=(pspecs, ospecs, P()),
        aux={"params_shape": params_shape, "opt_shape": opt_shape},
    )


# ---------------------------------------------------------------------------
# prefill / serve
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, mesh, scfg: StepConfig,
                       batch: int, seq_len: int,
                       with_frontend: Optional[Tuple[int, int]] = None,
                       chunks: Optional[int] = None,
                       cache_len: Optional[int] = None) -> StepBundle:
    """``fn(params, tokens[, frontend_embeds]) -> (cache, logits)``:
    forward over the prompt that also materializes the decode cache.

    ``chunks`` > 1 builds the **chunked streamed prefill** instead: the
    prompt runs as that many ART chunks through
    ``pipeline.chunk_pipeline_carried`` so chunk *k*'s forward overlaps
    chunk *k−1*'s cache write (``models/prefill.prefill_chunked``) —
    bit-identical cache and logits to the bulk program (archs outside
    ``supports_chunked_prefill`` fall back to bulk).

    ``cache_len`` sizes the ring buffer independently of the prompt
    (default: the prompt length) — the server's per-slot admission prefill
    sizes it to the batched cache's ``max_seq``."""
    params_shape, _ = _state_shapes(cfg, scfg)
    pspecs = param_pspecs(cfg, mesh, params_shape)
    constrain = _constraint_fn(cfg, mesh, scfg)
    dp = dp_axes(mesh)
    n_chunks = int(chunks or 1)
    cap = cache_len or seq_len

    def run(params, tokens, fe=None):
        if n_chunks > 1:
            return prefill_chunked(cfg, params, tokens, fe,
                                   cache_len=cap, n_chunks=n_chunks)
        return prefill(cfg, params, tokens, fe, cache_len=cap)

    arg_shapes = [jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)]
    arg_specs = [P(fit_axis(mesh, dp, batch), None)]
    if with_frontend is not None:
        n_tok, n_dim = with_frontend
        arg_shapes.append(
            jax.ShapeDtypeStruct((batch, n_tok, n_dim), jnp.float32))
        arg_specs.append(P(arg_specs[0][0], None, None))

    if with_frontend is None:
        def raw(params, tokens):
            return run(params, tokens)

        def fwd(params, tokens):
            with activation_sharding(constrain):
                return run(params, tokens)
    else:
        def raw(params, tokens, fe):
            return run(params, tokens, fe)

        def fwd(params, tokens, fe):
            with activation_sharding(constrain):
                return run(params, tokens, fe)

    cache_shape, logits_shape = jax.eval_shape(raw, params_shape, *arg_shapes)
    cspecs = cache_pspecs(cfg, mesh, cache_shape)
    lspec = P(arg_specs[0][0], None)

    fn = jax.jit(
        fwd,
        in_shardings=(to_shardings(mesh, pspecs),
                      *[NamedSharding(mesh, s) for s in arg_specs]),
        out_shardings=(to_shardings(mesh, cspecs), NamedSharding(mesh, lspec)))
    return StepBundle(
        fn=fn,
        in_specs=(pspecs, *arg_specs),
        out_specs=(cspecs, lspec),
        aux={"params_shape": params_shape, "cache_shape": cache_shape,
             "logits_shape": logits_shape},
    )


def _moe_decode_runner(cfg: ModelConfig, mesh, policy: TransportPolicy,
                       batch: int) -> Optional[Callable]:
    """The latency-mode EP decode runner, or None (dense-combine decode).

    ``policy.moe`` non-``xla`` with a usable ``expert`` axis batches the
    step's B decode tokens across the expert shards through
    ``Conduit("expert").all_to_all`` (``models/moe_ep.py`` with
    ``decode=True``).  Batches the mesh cannot split keep dense-combine —
    the weight-bound small-batch fallback."""
    if policy.moe == "xla" or cfg.family != "moe":
        return None
    if batch % mesh.size:
        warnings.warn(
            f"TransportPolicy.moe={policy.moe!r} requested but the serve "
            f"batch ({batch}) does not divide the mesh ({mesh.size}); "
            f"decode keeps the dense-combine fallback", stacklevel=3)
        return None
    return moe_ep.build_moe_ep_runner(
        cfg, mesh, transport=policy.moe, chunk_bytes=policy.chunk_bytes,
        decode=True)


def build_serve_step(cfg: ModelConfig, mesh, scfg: StepConfig,
                     batch: int, max_seq: int, *,
                     sample: bool = False,
                     block_size: int | None = None,
                     n_blocks: int | None = None) -> StepBundle:
    """``fn(params, cache, tokens) -> (cache, logits | token_ids)``: one
    batched decode step against the ring-buffer cache (continuous-batching
    inner loop; every cache row advances at its own per-slot position).

    The cache is **donated** — in/out shardings match leaf-for-leaf, so on
    backends with donation the step updates the ring buffers in place
    instead of copying the whole cache every token.

    ``sample=True`` returns greedy-sampled ``(B,)`` int32 token ids instead
    of the (B, V) logits: argmax runs on device and the server fetches one
    stacked id vector per step instead of syncing per-slot logits.

    ``block_size`` ≠ None switches the cache template to the paged block
    pool (``models/decode.init_paged_cache``): decode gathers each row's
    ring through its ``block_ids`` table and scatters the new row back into
    the pool — bit-identical to the contiguous path when every table fully
    backs the ring.  ``n_blocks`` defaults to parking blocks plus a full
    private table per row.

    ``TransportPolicy.moe`` ≠ ``xla`` (with an ``expert`` mesh axis and a
    mesh-divisible batch) swaps the dense-combine MoE decode for the
    expert-parallel conduit dispatch — see :func:`_moe_decode_runner`.
    """
    params_shape, _ = _state_shapes(cfg, scfg)
    pspecs = param_pspecs(cfg, mesh, params_shape)
    if block_size is not None:
        npb = paged_slot_blocks(cfg, max_seq, block_size)
        if n_blocks is None:
            n_blocks = batch * (1 + npb)
        cache_shape = jax.eval_shape(
            lambda: init_paged_cache(cfg, batch, max_seq, block_size,
                                     n_blocks))
    else:
        cache_shape = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))
    cspecs = cache_pspecs(cfg, mesh, cache_shape)
    dp = dp_axes(mesh)
    b_entry = fit_axis(mesh, dp, batch)
    tok_spec = P(b_entry)
    out_spec = P(b_entry) if sample else P(b_entry, None)
    moe_runner = _moe_decode_runner(cfg, mesh, scfg.resolved_transport(),
                                    batch)

    def fn_(params, cache, tokens):
        cache, logits = decode_step(cfg, params, cache, tokens,
                                    moe_runner=moe_runner)
        if sample:
            return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cache, logits

    fn = jax.jit(
        fn_,
        in_shardings=(to_shardings(mesh, pspecs),
                      to_shardings(mesh, cspecs),
                      NamedSharding(mesh, tok_spec)),
        out_shardings=(to_shardings(mesh, cspecs),
                       NamedSharding(mesh, out_spec)),
        donate_argnums=(1,))
    return StepBundle(
        fn=fn,
        in_specs=(pspecs, cspecs, tok_spec),
        out_specs=(cspecs, out_spec),
        aux={"params_shape": params_shape, "cache_shape": cache_shape},
    )


def build_prefill_chunk_step(cfg: ModelConfig, mesh, scfg: StepConfig,
                             batch: int, prompt_len: int,
                             lo: int, chunk_len: int,
                             with_frontend: Optional[Tuple[int, int]] = None,
                             ) -> StepBundle:
    """``fn(params, scratch, tokens[, frontend]) -> (scratch, logits)``: one
    incremental prefill chunk at static offset ``lo`` (the server's
    admission step), for whichever carry kind the arch declares
    (``configs.base.chunk_carry_spec``).

    The scratch is **donated** (same spec in and out), so each chunk
    updates the carry buffers in place; the final chunk's logits seed the
    request's first decode token.  ``with_frontend=(n_rows, dim)`` adds a
    frontend-embedding argument — the chunk's fe-row slice for vlm, the
    full frame tensor on the encdec chunk 0.  Requires
    ``models/prefill.chunk_support(cfg)``.
    """
    ok, why = chunk_support(cfg)
    assert ok, f"{cfg.name}: {why}"
    params_shape, _ = _state_shapes(cfg, scfg)
    pspecs = param_pspecs(cfg, mesh, params_shape)
    constrain = _constraint_fn(cfg, mesh, scfg)
    scratch_shape = jax.eval_shape(
        lambda: init_prefill_scratch(cfg, batch, prompt_len))
    sspecs = cache_pspecs(cfg, mesh, scratch_shape)
    dp = dp_axes(mesh)
    b_entry = fit_axis(mesh, dp, batch)
    tok_spec = P(b_entry, None)
    logit_spec = P(b_entry, None)

    if with_frontend is not None:
        fe_spec = P(b_entry, None, None)

        def fn_(params, scratch, tokens, frontend):
            with activation_sharding(constrain):
                return prefill_chunk(cfg, params, scratch, tokens, lo,
                                     frontend_embeds=frontend)

        fn = jax.jit(
            fn_,
            in_shardings=(to_shardings(mesh, pspecs),
                          to_shardings(mesh, sspecs),
                          NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, fe_spec)),
            out_shardings=(to_shardings(mesh, sspecs),
                           NamedSharding(mesh, logit_spec)),
            donate_argnums=(1,))
        return StepBundle(
            fn=fn,
            in_specs=(pspecs, sspecs, tok_spec, fe_spec),
            out_specs=(sspecs, logit_spec),
            aux={"params_shape": params_shape,
                 "scratch_shape": scratch_shape,
                 "lo": lo, "chunk_len": chunk_len,
                 "with_frontend": with_frontend},
        )

    def fn_(params, scratch, tokens):
        with activation_sharding(constrain):
            return prefill_chunk(cfg, params, scratch, tokens, lo)

    fn = jax.jit(
        fn_,
        in_shardings=(to_shardings(mesh, pspecs),
                      to_shardings(mesh, sspecs),
                      NamedSharding(mesh, tok_spec)),
        out_shardings=(to_shardings(mesh, sspecs),
                       NamedSharding(mesh, logit_spec)),
        donate_argnums=(1,))
    return StepBundle(
        fn=fn,
        in_specs=(pspecs, sspecs, tok_spec),
        out_specs=(sspecs, logit_spec),
        aux={"params_shape": params_shape, "scratch_shape": scratch_shape,
             "lo": lo, "chunk_len": chunk_len},
    )


def build_slot_write_step(cfg: ModelConfig, mesh, batch: int,
                          max_seq: int) -> StepBundle:
    """``fn(cache, slot_cache, i) -> cache``: write a single-request cache
    (batch 1) into row ``i`` of every leaf of the batched decode cache —
    the per-slot admission PUT of the continuous-batching server.  The
    batched cache is **donated**; only row ``i`` moves."""
    full_shape = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))
    one_shape = jax.eval_shape(lambda: init_cache(cfg, 1, max_seq))
    # the batch axis of each leaf, found structurally (it differs per
    # family: (L, B, ...) stacks vs (B, ...) bookkeeping)
    two_shape = jax.eval_shape(lambda: init_cache(cfg, 2, max_seq))
    baxes = {
        k: next(i for i, (a, b) in enumerate(
            zip(two_shape[k].shape, one_shape[k].shape)) if a != b)
        for k in one_shape
    }
    cspecs = cache_pspecs(cfg, mesh, full_shape)
    sspecs = cache_pspecs(cfg, mesh, one_shape)

    def fn_(cache, slot, i):
        return {
            k: lax.dynamic_update_slice_in_dim(
                cache[k], slot[k].astype(cache[k].dtype), i, axis=baxes[k])
            for k in cache
        }

    fn = jax.jit(
        fn_,
        in_shardings=(to_shardings(mesh, cspecs),
                      to_shardings(mesh, sspecs), _scalar_sharding(mesh)),
        out_shardings=to_shardings(mesh, cspecs),
        donate_argnums=(0,))
    return StepBundle(
        fn=fn,
        in_specs=(cspecs, sspecs, P()),
        out_specs=cspecs,
        aux={"cache_shape": full_shape, "batch_axes": baxes},
    )


def build_block_write_step(cfg: ModelConfig, mesh, batch: int,
                           max_seq: int, block_size: int, n_blocks: int,
                           n_write: int) -> StepBundle:
    """``fn(cache, bk, bv, dst, table_row, slot_pos_row, pos, i) -> cache``:
    push ``n_write`` finished prefill blocks into the paged pool and install
    row ``i``'s block table — the block-granular admission PUT.

    ``bk``/``bv`` are ``(L, n_write, Hkv, blk, hd)`` block stacks (from
    ``models/prefill.scratch_to_blocks``), ``dst`` the ``(n_write,)`` global
    pool ids they land in, ``table_row`` the full ``(S_buf/blk,)`` table for
    the slot (private ids plus any ref-counted shared-prefix ids, which are
    *not* rewritten — copy-on-write sharing).  The pool cache is **donated**;
    only the written blocks and row ``i``'s bookkeeping move.  One bundle
    per ``n_write`` — the server caches them per distinct prefix-hit depth.
    """
    full_shape = jax.eval_shape(
        lambda: init_paged_cache(cfg, batch, max_seq, block_size, n_blocks))
    cspecs = cache_pspecs(cfg, mesh, full_shape)

    def fn_(cache, bk, bv, dst, table_row, slot_pos_row, pos, i):
        out = dict(cache)
        out["kp"] = cache["kp"].at[:, dst].set(bk.astype(cache["kp"].dtype))
        out["vp"] = cache["vp"].at[:, dst].set(bv.astype(cache["vp"].dtype))
        out["block_ids"] = lax.dynamic_update_slice_in_dim(
            cache["block_ids"], table_row[None], i, axis=0)
        out["slot_pos"] = lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], slot_pos_row[None], i, axis=0)
        out["pos"] = lax.dynamic_update_slice_in_dim(
            cache["pos"], pos[None], i, axis=0)
        return out

    # payload inputs keep whatever sharding prefill left them with (the
    # scatter re-lays them out); only the donated pool is pinned.
    fn = jax.jit(
        fn_,
        in_shardings=(to_shardings(mesh, cspecs),) + (None,) * 7,
        out_shardings=to_shardings(mesh, cspecs),
        donate_argnums=(0,))
    return StepBundle(
        fn=fn,
        in_specs=(cspecs, P(), P(), P(), P(), P(), P(), P()),
        out_specs=cspecs,
        aux={"cache_shape": full_shape, "n_write": n_write,
             "block_size": block_size},
    )


__all__ = [
    "StepConfig", "StepBundle", "TransportPolicy", "refit_step_config",
    "build_init",
    "build_train_step", "build_prefill_step", "build_serve_step",
    "build_prefill_chunk_step", "build_slot_write_step",
    "build_block_write_step", "MeshAxes",
]
